"""sacheck core: findings, suppressions, baseline, and the pass runner.

sacheck is a repo-invariant static-analysis suite (PR 9).  Unlike a
general linter, every pass encodes one invariant THIS codebase's
correctness story rests on (engine<->simulator twin parity, unit-suffix
discipline, FabricAccountant-mediated accounting, jit purity,
determinism) — invariants that were previously enforced only at runtime
by property tests and therefore drifted silently between PRs.

Vocabulary:

  - **Finding** — one violation of one pass, anchored to a file + line.
    Its *fingerprint* is line-number independent (pass, path, code, and
    the normalized source line), so baselines survive unrelated edits.
  - **Suppression** — an inline ``# sacheck: disable=<pass> -- reason``
    comment on the violating line (or the line directly above).  The
    reason is MANDATORY: a reasonless disable does not suppress and is
    itself reported (code ``missing-reason``), so every exception to an
    invariant is justified in the diff that introduces it.
  - **Baseline** — a committed JSON set of fingerprints recording
    pre-existing findings.  Baselined findings are reported as such but
    do not fail the run; every NEW finding does.  Regenerate with
    ``python -m tools.sacheck --write-baseline`` (entries that stopped
    firing are pruned automatically).
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*sacheck:\s*disable=([A-Za-z0-9_,-]+)"
    r"(?:\s+--\s+(?P<reason>\S.*))?")

#: pass name used for meta-findings about the suppression syntax itself
SUPPRESSION_PASS = "suppression"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation: ``pass_name`` names the pass, ``code`` the specific
    rule inside it, ``line`` anchors it, and ``message`` explains it."""

    pass_name: str
    path: str            # repo-relative posix path
    line: int            # 1-indexed
    code: str
    message: str
    line_text: str = ""  # normalized source line (fingerprint stability)

    @property
    def fingerprint(self) -> str:
        # deliberately line-NUMBER free: unrelated edits above a
        # baselined finding must not turn it into a "new" violation
        return "|".join((self.pass_name, self.path, self.code,
                         self.line_text.strip()))

    def render(self) -> str:
        return (f"{self.path}:{self.line}: "
                f"[{self.pass_name}/{self.code}] {self.message}")


@dataclasses.dataclass
class Suppression:
    passes: Tuple[str, ...]
    reason: Optional[str]
    line: int

    def covers(self, pass_name: str) -> bool:
        return pass_name in self.passes or "all" in self.passes


class SourceFile:
    """One parsed source file: text, AST, and inline suppressions."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree: Optional[ast.Module] = None
        self.parse_error: Optional[str] = None
        try:
            self.tree = ast.parse(text, filename=path)
        except SyntaxError as e:  # surfaced as a finding by the runner
            self.parse_error = f"{e.msg} (line {e.lineno})"
        self.suppressions: Dict[int, Suppression] = {}
        for i, raw in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(raw)
            if m:
                names = tuple(p.strip() for p in m.group(1).split(",")
                              if p.strip())
                self.suppressions[i] = Suppression(names, m.group("reason"),
                                                   i)

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1]
        return ""

    def suppression_for(self, line: int, pass_name: str
                        ) -> Optional[Suppression]:
        """A suppression covers the line it sits on and the line below it
        (i.e. look at the finding's own line, then the line above)."""
        for cand in (line, line - 1):
            sup = self.suppressions.get(cand)
            if sup is not None and sup.covers(pass_name):
                return sup
        return None


@dataclasses.dataclass
class CheckContext:
    """Everything a pass needs: the repo root, the parsed files, and the
    repo-specific configuration (``tools/sacheck/config.py`` by default;
    tests inject minimal configs over fixture trees)."""

    root: Path
    files: Dict[str, SourceFile]
    config: "object"

    def file(self, relpath: str) -> Optional[SourceFile]:
        return self.files.get(relpath)

    def finding(self, pass_name: str, relpath: str, line: int, code: str,
                message: str) -> Finding:
        sf = self.files.get(relpath)
        text = sf.line_text(line) if sf is not None else ""
        return Finding(pass_name, relpath, line, code, message, text)


def collect_files(root: Path, subdirs: Iterable[str]) -> Dict[str, SourceFile]:
    files: Dict[str, SourceFile] = {}
    for sub in subdirs:
        base = root / sub
        if base.is_file():
            paths = [base]
        else:
            paths = sorted(base.rglob("*.py"))
        for p in paths:
            rel = p.relative_to(root).as_posix()
            files[rel] = SourceFile(rel, p.read_text())
    return files


# ---------------------------------------------------------------------------
# shared AST helpers (used by several passes)
# ---------------------------------------------------------------------------


def dataclass_fields(tree: ast.Module, class_name: str
                     ) -> List[Tuple[str, int]]:
    """(name, lineno) of every annotated field of ``class_name``.

    ``InitVar`` pseudo-fields (deprecated constructor aliases) and
    ``ClassVar`` annotations are skipped — they are not twins."""
    out: List[Tuple[str, int]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == class_name:
            for stmt in node.body:
                if (isinstance(stmt, ast.AnnAssign)
                        and isinstance(stmt.target, ast.Name)):
                    ann = ast.dump(stmt.annotation)
                    if "InitVar" in ann or "ClassVar" in ann:
                        continue
                    out.append((stmt.target.id, stmt.lineno))
    return out


def attribute_chain(node: ast.AST) -> List[str]:
    """``a.b.c`` -> ["a", "b", "c"]; [] when the base is not a Name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return []


def call_name(node: ast.Call) -> str:
    """Trailing name of the called object: ``np.random.rand`` -> "rand",
    ``set(...)`` -> "set"."""
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


def load_baseline(path: Path) -> List[str]:
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    return list(data.get("entries", []))


def save_baseline(path: Path, fingerprints: Iterable[str]) -> None:
    data = {
        "comment": ("sacheck baseline: pre-existing findings recorded so "
                    "only NEW violations fail CI.  Regenerate with "
                    "`python -m tools.sacheck --write-baseline`."),
        "entries": sorted(set(fingerprints)),
    }
    path.write_text(json.dumps(data, indent=1) + "\n")


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunResult:
    new: List[Finding]                 # fail the run
    baselined: List[Finding]          # known, recorded in the baseline
    suppressed: List[Tuple[Finding, Suppression]]
    stale_baseline: List[str]         # entries that no longer fire

    @property
    def ok(self) -> bool:
        return not self.new


def run_passes(ctx: CheckContext,
               passes: Dict[str, Callable[[CheckContext], List[Finding]]],
               baseline: Iterable[str] = ()) -> RunResult:
    """Run every pass, apply suppressions (reasonless ones become
    ``missing-reason`` findings), then split results against the
    baseline."""
    raw: List[Finding] = []
    for rel, sf in ctx.files.items():
        if sf.parse_error:
            raw.append(ctx.finding(SUPPRESSION_PASS, rel, 1, "syntax-error",
                                   f"cannot parse: {sf.parse_error}"))
    for name, fn in passes.items():
        raw.extend(fn(ctx))

    kept: List[Finding] = []
    suppressed: List[Tuple[Finding, Suppression]] = []
    seen_reasonless: set = set()
    for f in raw:
        sf = ctx.files.get(f.path)
        sup = (sf.suppression_for(f.line, f.pass_name)
               if sf is not None else None)
        if sup is None:
            kept.append(f)
        elif sup.reason:
            suppressed.append((f, sup))
        else:
            kept.append(f)          # reasonless: does NOT suppress
            key = (f.path, sup.line)
            if key not in seen_reasonless:
                seen_reasonless.add(key)
                kept.append(ctx.finding(
                    SUPPRESSION_PASS, f.path, sup.line, "missing-reason",
                    "sacheck suppression without a reason — write "
                    "`# sacheck: disable=<pass> -- <why this is ok>`"))

    base = set(baseline)
    new = [f for f in kept if f.fingerprint not in base]
    known = [f for f in kept if f.fingerprint in base]
    fired = {f.fingerprint for f in kept}
    stale = sorted(base - fired)
    # deterministic report order
    new.sort(key=lambda f: (f.path, f.line, f.pass_name, f.code))
    known.sort(key=lambda f: (f.path, f.line, f.pass_name, f.code))
    return RunResult(new=new, baselined=known, suppressed=suppressed,
                     stale_baseline=stale)
