"""accounting-boundary: ``TrafficStats`` counters may only be mutated
inside ``core/traffic.py`` (FabricAccountant / OverlapQueue).

Why this invariant exists: TrafficStats is the ONE schema every layer
(engine, simulator, SACSystem) reports through, and the paper's QoS /
per-segment story (PAPER.md §4) depends on every byte and second being
booked by the accountant — which validates device ids at the boundary
(``_resolve_device``), routes charges per segment, and keeps the
issued/exposed and demand/speculative splits consistent.  A caller that
reaches around the accountant and does ``acct.stats.prefetch_bytes += x``
gets the number in the total but skips the routing/validation/QoS
bookkeeping, and the engine/simulator twins silently diverge (this
exact bug shipped twice in serving/simulator.py before PR 9).

Detection: an assignment or augmented assignment whose target is
``<anything>.stats.<counter>`` (or a subscript of it), or
``stats.<counter>`` on a bare receiver named like a stats object, where
``<counter>`` is a field of the TrafficStats dataclass — parsed live
from core/traffic.py, so new counters are covered the day they are
added.  Mutations inside core/traffic.py itself are the accountant's
own and legal.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from tools.sacheck.core import (CheckContext, Finding, dataclass_fields)

NAME = "accounting-boundary"


def _traffic_fields(ctx: CheckContext) -> Optional[Set[str]]:
    sf = ctx.file(ctx.config.accounting_home)
    if sf is None or sf.tree is None:
        return None
    fields = dataclass_fields(sf.tree, ctx.config.traffic_stats_class)
    return {n for n, _ in fields} or None


def _mutated_counter(target: ast.AST, ctx: CheckContext,
                     fields: Set[str]) -> Optional[str]:
    """Counter name when ``target`` writes a TrafficStats field through a
    ``.stats.`` (or bare ``stats``) receiver; else None."""
    while isinstance(target, ast.Subscript):
        target = target.value
    if not isinstance(target, ast.Attribute) or target.attr not in fields:
        return None
    base = target.value
    if isinstance(base, ast.Attribute) and base.attr == "stats":
        return target.attr
    if (isinstance(base, ast.Name)
            and base.id in ctx.config.stats_receiver_names):
        return target.attr
    return None


def run(ctx: CheckContext) -> List[Finding]:
    fields = _traffic_fields(ctx)
    out: List[Finding] = []
    if fields is None:
        out.append(Finding(
            NAME, ctx.config.accounting_home, 1, "missing-schema",
            f"cannot locate {ctx.config.traffic_stats_class} fields in "
            f"{ctx.config.accounting_home} — the boundary is undefined"))
        return out
    for rel, sf in ctx.files.items():
        if (sf.tree is None or rel == ctx.config.accounting_home
                or not rel.startswith("src/")):
            continue
        for node in ast.walk(sf.tree):
            targets: List[ast.AST] = []
            if isinstance(node, ast.AugAssign):
                targets = [node.target]
            elif isinstance(node, ast.Assign):
                targets = list(node.targets)
            for t in targets:
                counter = _mutated_counter(t, ctx, fields)
                if counter is not None:
                    out.append(ctx.finding(
                        NAME, rel, node.lineno, "direct-mutation",
                        f"direct mutation of TrafficStats.{counter} "
                        f"outside {ctx.config.accounting_home} — route "
                        f"it through a FabricAccountant method so "
                        f"routing/validation/QoS bookkeeping stay "
                        f"consistent"))
    return out
