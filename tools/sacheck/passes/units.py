"""unit-suffix: accounting/config attributes carry their unit in their
name (``_s`` seconds, ``_bytes``, ``_tokens``, ``_frac``), and
additive arithmetic must not mix two different unit suffixes.

Why this invariant exists: the whole modeled-performance story is
numbers flowing between layers — fabric seconds, demand bytes, token
counts, budget fractions.  A classic drift bug is adding a seconds
counter to a bytes counter (both plain floats, both "demand"), which no
type checker catches.  The suffix convention makes the unit part of the
name; this pass enforces it where it is mechanically checkable:

  - ``a_s + b_bytes`` (or ``-``, ``+=``, ``-=``, or a comparison)
    between two expressions whose inferred suffixes DIFFER is flagged.
  - multiplication/division are treated as explicit conversions
    (``bytes / bandwidth`` is how you turn bytes into seconds) and
    reset the inferred unit.

Inference is name-based and conservative: an expression with no
recognizable suffix has unknown unit and never participates in a
violation, so the pass has no opinion about ``t + dur`` — only about
provably mixed units.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from tools.sacheck.core import CheckContext, Finding

NAME = "units"

#: order matters: match the longest suffix first ("_bytes" before "_s"
#: is irrelevant here, but "_s" must not swallow e.g. "_tokens")
_AGG_FUNCS = {"max", "min", "sum", "abs", "sorted"}


def _suffix_unit(name: str, suffixes) -> Optional[str]:
    for suf in sorted(suffixes, key=len, reverse=True):
        if name.endswith(suf) and len(name) > len(suf):
            return suf
    return None


def _unit(node: ast.AST, suffixes) -> Optional[str]:
    """Inferred unit suffix of an expression, or None when unknown."""
    if isinstance(node, ast.Name):
        return _suffix_unit(node.id, suffixes)
    if isinstance(node, ast.Attribute):
        return _suffix_unit(node.attr, suffixes)
    if isinstance(node, ast.Subscript):
        return _unit(node.value, suffixes)
    if isinstance(node, ast.UnaryOp):
        return _unit(node.operand, suffixes)
    if isinstance(node, ast.BinOp):
        if isinstance(node.op, (ast.Add, ast.Sub)):
            lu = _unit(node.left, suffixes)
            ru = _unit(node.right, suffixes)
            # additive: the unit propagates through unknown operands
            # (consistency of known operands is checked by the visitor)
            return lu or ru
        return None          # *, /, etc. convert units
    if isinstance(node, ast.Call):
        f = node.func
        fname = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else "")
        if fname in _AGG_FUNCS:
            units = [_unit(a, suffixes) for a in node.args
                     if not isinstance(a, (ast.GeneratorExp, ast.Starred))]
            known = [u for u in units if u is not None]
            if known and all(u == known[0] for u in known):
                return known[0]
            return None
        # a call's unit is declared by its name: model.prefill_s(ctx)
        # returns seconds, stats.segment_demand_s() returns seconds
        return _suffix_unit(fname, suffixes)
    if isinstance(node, ast.IfExp):
        return (_unit(node.body, suffixes)
                or _unit(node.orelse, suffixes))
    return None


class _Visitor(ast.NodeVisitor):
    def __init__(self, ctx: CheckContext, path: str):
        self.ctx = ctx
        self.path = path
        self.suffixes = ctx.config.unit_suffixes
        self.findings: List[Finding] = []

    def _check_pair(self, a: ast.AST, b: ast.AST, node: ast.AST,
                    what: str) -> None:
        ua = _unit(a, self.suffixes)
        ub = _unit(b, self.suffixes)
        if ua is not None and ub is not None and ua != ub:
            self.findings.append(self.ctx.finding(
                NAME, self.path, node.lineno, "unit-mix",
                f"{what} mixes units {ua} and {ub} without an explicit "
                f"conversion (multiply/divide by a rate, or rename one "
                f"side to its true unit)"))

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, (ast.Add, ast.Sub)):
            self._check_pair(node.left, node.right, node,
                             "additive arithmetic")
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if isinstance(node.op, (ast.Add, ast.Sub)):
            self._check_pair(node.target, node.value, node,
                             "augmented assignment")
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare) -> None:
        operands = [node.left] + list(node.comparators)
        for a, b in zip(operands, operands[1:]):
            self._check_pair(a, b, node, "comparison")
        self.generic_visit(node)


def run(ctx: CheckContext) -> List[Finding]:
    out: List[Finding] = []
    for rel, sf in ctx.files.items():
        if sf.tree is None or not rel.startswith("src/"):
            continue
        v = _Visitor(ctx, rel)
        v.visit(sf.tree)
        out.extend(v.findings)
    return out
