"""jit-purity: functions reachable from ``jax.jit`` / ``pl.pallas_call``
call sites must stay trace-pure.

Why this invariant exists: the engine's hot path is jitted once and
replayed; anything Python-side inside it either (a) runs at TRACE time
only and silently freezes (``time.time()``, ``random.random()``, global
mutation — the value is baked into the compiled graph, so "per-step"
randomness isn't), or (b) forces a concretization error / silent
recompile (``float(x)`` on a traced array).  All four bug classes pass
unit tests on the first trace and corrupt steady-state serving.

Detection (per module, documented approximation):

  - roots: functions decorated with ``jit``/``pallas_call`` (bare,
    dotted, or inside ``functools.partial``), functions passed as
    arguments to a ``jit(...)``/``pallas_call(...)`` call, and pallas
    kernel bodies (first argument of ``pallas_call``);
  - reachability: same-module calls by bare name (``f(...)``) or self
    method (``self.f(...)``) are followed transitively;
  - inside reachable functions, flag: ``time.*`` calls, ``random.*`` /
    ``np.random.*`` calls, ``global`` declarations (module-global
    mutation), and ``float()/int()/bool()`` applied to an expression
    containing one of the function's own parameters — unless that
    parameter is listed in the root's ``static_argnames`` (static args
    are Python values, casting them is fine).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from tools.sacheck.core import CheckContext, Finding, attribute_chain

NAME = "jit-purity"

_JIT_NAMES = {"jit", "pallas_call"}
_CAST_NAMES = {"float", "int", "bool"}


def _callable_name(node: ast.AST) -> str:
    """Trailing name of a possibly-dotted callable expression."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _static_argnames(call: ast.Call) -> Set[str]:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            vals: Set[str] = set()
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    vals.add(n.value)
            return vals
    return set()


class _ModuleIndex:
    """Functions of one module, jit roots, and the bare-name call graph."""

    def __init__(self, tree: ast.Module):
        self.funcs: Dict[str, ast.FunctionDef] = {}
        self.roots: Dict[str, Set[str]] = {}   # fn name -> static argnames
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.funcs.setdefault(node.name, node)
        # decorator roots: @jax.jit / @functools.partial(jax.jit, ...)
        for name, fn in self.funcs.items():
            for dec in fn.decorator_list:
                statics: Set[str] = set()
                hit = _callable_name(dec) in _JIT_NAMES
                if isinstance(dec, ast.Call):
                    if _callable_name(dec.func) in _JIT_NAMES:
                        hit = True
                        statics = _static_argnames(dec)
                    else:  # partial(jax.jit, static_argnames=...)
                        for a in dec.args:
                            if _callable_name(a) in _JIT_NAMES:
                                hit = True
                        if hit:
                            statics = _static_argnames(dec)
                if hit:
                    self._add_root(name, statics)
        # call-site roots: jax.jit(f), pl.pallas_call(kernel, ...),
        # jit(functools.partial(f, ...))
        for node in ast.walk(tree):
            if (isinstance(node, ast.Call)
                    and _callable_name(node.func) in _JIT_NAMES):
                statics = _static_argnames(node)
                for arg in node.args:
                    if isinstance(arg, ast.Call):  # partial(f, ...)
                        args: List[ast.AST] = list(arg.args)
                    else:
                        args = [arg]
                    for a in args:
                        if isinstance(a, ast.Name) and a.id in self.funcs:
                            self._add_root(a.id, statics)

    def _add_root(self, name: str, statics: Set[str]) -> None:
        self.roots.setdefault(name, set()).update(statics)

    def reachable(self) -> Dict[str, Set[str]]:
        """fn name -> static argnames inherited from the nearest root."""
        seen: Dict[str, Set[str]] = {}
        stack = list(self.roots.items())
        while stack:
            name, statics = stack.pop()
            if name in seen:
                continue
            seen[name] = statics
            fn = self.funcs.get(name)
            if fn is None:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                f = node.func
                callee = None
                if isinstance(f, ast.Name) and f.id in self.funcs:
                    callee = f.id
                elif (isinstance(f, ast.Attribute)
                      and isinstance(f.value, ast.Name)
                      and f.value.id == "self" and f.attr in self.funcs):
                    callee = f.attr
                if callee is not None and callee not in seen:
                    # statics only shield the ROOT's own parameters;
                    # callees see traced values
                    stack.append((callee, set()))
        return seen


def _check_function(ctx: CheckContext, path: str, fn: ast.FunctionDef,
                    statics: Set[str]) -> List[Finding]:
    out: List[Finding] = []
    params = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                              + fn.args.kwonlyargs)} - {"self"} - statics
    for node in ast.walk(fn):
        if isinstance(node, ast.Global):
            out.append(ctx.finding(
                NAME, path, node.lineno, "global-mutation",
                f"`global` inside jit-reachable `{fn.name}` — module "
                f"state mutated at trace time is frozen into the "
                f"compiled graph"))
        if not isinstance(node, ast.Call):
            continue
        chain = attribute_chain(node.func)
        if chain[:1] == ["time"]:
            out.append(ctx.finding(
                NAME, path, node.lineno, "time-call",
                f"time.{chain[-1]} inside jit-reachable `{fn.name}` "
                f"runs once at trace time, not per step"))
        elif (chain[:1] == ["random"]
              or chain[:2] in (["np", "random"], ["numpy", "random"])):
            out.append(ctx.finding(
                NAME, path, node.lineno, "rng-call",
                f"Python-side RNG ({'.'.join(chain)}) inside "
                f"jit-reachable `{fn.name}` is evaluated at trace time "
                f"— use jax.random with a threaded key"))
        elif (isinstance(node.func, ast.Name)
              and node.func.id in _CAST_NAMES and node.args):
            used = _names_in(node.args[0]) & params
            if used:
                out.append(ctx.finding(
                    NAME, path, node.lineno, "traced-cast",
                    f"{node.func.id}() applied to traced argument(s) "
                    f"{sorted(used)} of jit-reachable `{fn.name}` — "
                    f"concretizes the tracer (error or silent "
                    f"recompile); keep it an array op or mark the "
                    f"argument static"))
    return out


def run(ctx: CheckContext) -> List[Finding]:
    out: List[Finding] = []
    for rel, sf in ctx.files.items():
        if sf.tree is None or not rel.startswith("src/"):
            continue
        idx = _ModuleIndex(sf.tree)
        if not idx.roots:
            continue
        for name, statics in sorted(idx.reachable().items()):
            fn = idx.funcs.get(name)
            if fn is not None:
                out.extend(_check_function(ctx, rel, fn, statics))
    return out
