"""determinism: no unseeded global-state RNG in src/, and no iteration
over unordered ``set`` values in core//serving/ accounting paths.

Why this invariant exists: the repo's headline property is bit-identical
decoding and reproducible virtual-clock timing — every BENCH_* number
and every parity test (engine == simulator to float precision) depends
on a run being a pure function of (trace, seed, knobs).  Two leak
channels are easy to introduce and brutal to debug:

  - **global RNG state** (``random.random()``, ``np.random.rand()``):
    the result depends on everything that touched the interpreter-wide
    generator before you, including test ordering.  Seeded generator
    objects (``np.random.default_rng(seed)``, ``random.Random(seed)``,
    ``jax.random.PRNGKey``) are the sanctioned alternative and are not
    flagged.
  - **set iteration order** in accounting paths: ``set`` order is
    hash-based; summing floats or booking per-device charges in set
    order changes low bits between runs/platforms, which the
    float-exact parity gates then catch hundreds of steps later.
    Iterating a ``sorted(...)`` of the set is the sanctioned form.
    (Detection is syntactic: set literals/constructors/comprehensions
    and ``|&-^`` combinations of them in ``for``/comprehension iterator
    position; a plain variable of set type is not resolvable without
    type inference and is out of scope.)
"""
from __future__ import annotations

import ast
from typing import List

from tools.sacheck.core import CheckContext, Finding, attribute_chain

NAME = "determinism"

#: module-level (global-state) functions of `random`
_PY_GLOBAL_RNG = {
    "random", "randint", "randrange", "uniform", "choice", "choices",
    "shuffle", "sample", "gauss", "normalvariate", "betavariate",
    "expovariate", "seed", "getrandbits", "triangular",
}
#: legacy global-state functions of `np.random` (default_rng is fine)
_NP_GLOBAL_RNG = {
    "rand", "randn", "randint", "random", "random_sample", "choice",
    "shuffle", "permutation", "uniform", "normal", "exponential",
    "poisson", "pareto", "seed", "standard_normal", "beta", "gamma",
}


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Name) and f.id in ("set", "frozenset"):
            return True
        if isinstance(f, ast.Attribute) and f.attr in (
                "union", "intersection", "difference",
                "symmetric_difference"):
            # x.union(y) etc. on a set-ish receiver; only claim set-ness
            # when the receiver itself is provably a set expression
            return _is_set_expr(f.value)
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


def run(ctx: CheckContext) -> List[Finding]:
    out: List[Finding] = []
    for rel, sf in ctx.files.items():
        if sf.tree is None or not rel.startswith("src/"):
            continue
        in_scope = rel.startswith(tuple(ctx.config.determinism_scopes))
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                chain = attribute_chain(node.func)
                if (chain[:1] == ["random"] and len(chain) == 2
                        and chain[1] in _PY_GLOBAL_RNG):
                    out.append(ctx.finding(
                        NAME, rel, node.lineno, "global-rng",
                        f"unseeded global-state RNG {'.'.join(chain)} — "
                        f"results depend on interpreter-wide state; use "
                        f"random.Random(seed) or np.random.default_rng"))
                elif (chain[:2] in (["np", "random"], ["numpy", "random"])
                      and len(chain) == 3 and chain[2] in _NP_GLOBAL_RNG):
                    out.append(ctx.finding(
                        NAME, rel, node.lineno, "global-rng",
                        f"legacy numpy global RNG {'.'.join(chain)} — "
                        f"use np.random.default_rng(seed) so traces are "
                        f"a pure function of the seed"))
            if in_scope:
                iters: List[ast.AST] = []
                if isinstance(node, ast.For):
                    iters.append(node.iter)
                elif isinstance(node, (ast.ListComp, ast.SetComp,
                                       ast.DictComp, ast.GeneratorExp)):
                    iters.extend(g.iter for g in node.generators)
                for it in iters:
                    if _is_set_expr(it):
                        out.append(ctx.finding(
                            NAME, rel, node.lineno, "set-iteration",
                            "iteration over unordered set values in an "
                            "accounting path — wrap in sorted(...) so "
                            "float accumulation order is deterministic"))
    return out
