"""Pass registry: name -> run(ctx) -> [Finding]."""
from tools.sacheck.passes import (accounting_boundary, determinism,
                                  jit_purity, twin_coverage, units)

PASSES = {
    twin_coverage.NAME: twin_coverage.run,
    units.NAME: units.run,
    accounting_boundary.NAME: accounting_boundary.run,
    jit_purity.NAME: jit_purity.run,
    determinism.NAME: determinism.run,
}
