"""twin-coverage: every serving-relevant ``SACConfig`` knob must have a
``SimConfig`` twin with a MATCHING NAME and a ``launch/serve.py`` flag.

Why this invariant exists: the engine (real jitted decode) and the
simulator (analytic event loop) are deliberate twins — every PR's
acceptance rests on parity tests that run the same knob through both.
A knob that exists on one side only, or under a different name, silently
falls out of the parity harness: the next person sweeps
``replicate_horizon_steps`` on the engine and ``replicate_horizon`` on
the simulator and compares incomparable runs.  Exceptions are allowed
but must be *justified* in tools/sacheck/config.py (twin_renames /
twin_non_serving / flag_renames / flag_exempt) — and a justification
whose subject disappeared is itself reported (stale-allowlist), so the
allowlist cannot rot.

Shared-policy escape hatch (PR 10): a knob consumed ONLY through a
shared control-plane object under ``SacheckConfig.policy_package``
(declared in the policy module's module-level ``CONSUMED_KNOBS``
tuple) needs no same-named SimConfig twin — there is nothing to twin,
both layers literally run the same code.  The serve.py flag is still
required (operators must reach every knob), a declared knob that names
a vanished SACConfig field is reported (stale-policy-knob), and an
allowlist entry for a consumed knob is reported as redundant
(redundant-allowlist) — the declaration supersedes the justification.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from tools.sacheck.core import (CheckContext, Finding, dataclass_fields)

NAME = "twin-coverage"


def _consumed_knobs(ctx: CheckContext,
                    prefix: str) -> Dict[str, Tuple[str, int]]:
    """knob -> (policy file, line) for every string in a module-level
    ``CONSUMED_KNOBS`` tuple/list under the policy package prefix."""
    consumed: Dict[str, Tuple[str, int]] = {}
    if not prefix:
        return consumed
    for rel in sorted(ctx.files):
        if not rel.startswith(prefix):
            continue
        sf = ctx.files[rel]
        if sf.tree is None:
            continue
        for node in sf.tree.body:
            if not (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == "CONSUMED_KNOBS"
                    and isinstance(node.value, (ast.Tuple, ast.List))):
                continue
            for elt in node.value.elts:
                if (isinstance(elt, ast.Constant)
                        and isinstance(elt.value, str)):
                    consumed.setdefault(elt.value, (rel, elt.lineno))
    return consumed


def _serve_flags(tree: ast.Module) -> Set[str]:
    flags: Set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            for arg in node.args:
                if (isinstance(arg, ast.Constant)
                        and isinstance(arg.value, str)
                        and arg.value.startswith("--")):
                    flags.add(arg.value)
    return flags


def run(ctx: CheckContext) -> List[Finding]:
    cfg = ctx.config
    out: List[Finding] = []

    sac_sf = ctx.file(cfg.sac_config_path)
    sim_sf = ctx.file(cfg.sim_config_path)
    serve_sf = ctx.file(cfg.serve_path)
    for path, sf in ((cfg.sac_config_path, sac_sf),
                     (cfg.sim_config_path, sim_sf),
                     (cfg.serve_path, serve_sf)):
        if sf is None or sf.tree is None:
            out.append(Finding(NAME, path, 1, "missing-file",
                               f"twin-coverage needs {path} but it is "
                               "absent or unparsable"))
    if any(sf is None or sf.tree is None
           for sf in (sac_sf, sim_sf, serve_sf)):
        return out

    sac_fields = dataclass_fields(sac_sf.tree, cfg.sac_config_class)
    sim_fields = {n for n, _ in dataclass_fields(sim_sf.tree,
                                                 cfg.sim_config_class)}
    flags = _serve_flags(serve_sf.tree)
    if not sac_fields:
        out.append(Finding(NAME, cfg.sac_config_path, 1, "missing-class",
                           f"class {cfg.sac_config_class} has no fields "
                           "(or was renamed away)"))
        return out

    # shared-policy consumption (PR 10): knobs routed exclusively
    # through the policy package need no same-named SimConfig twin
    pkg = getattr(cfg, "policy_package", "")
    consumed = _consumed_knobs(ctx, pkg + "/" if pkg else "")

    sac_names = {n for n, _ in sac_fields}
    for name, line in sac_fields:
        if name in cfg.twin_non_serving:
            continue
        # --- SimConfig twin (or shared-policy consumption) ---
        if name in consumed:
            # both layers construct the same policy object; requiring a
            # float-parity twin here would re-create the duplication the
            # policy package removed.  A leftover allowlist entry is
            # reported below (redundant-allowlist).
            pass
        elif name in cfg.twin_renames:
            twin, why = cfg.twin_renames[name]
            if twin is not None and twin not in sim_fields:
                out.append(ctx.finding(
                    NAME, cfg.sac_config_path, line, "stale-rename",
                    f"SACConfig.{name} is allowlisted as twinned to "
                    f"SimConfig.{twin}, but that field no longer exists "
                    f"(justification was: {why})"))
        elif name not in sim_fields:
            out.append(ctx.finding(
                NAME, cfg.sac_config_path, line, "missing-twin",
                f"serving knob SACConfig.{name} has no SimConfig field "
                f"of the same name — add the analytic twin, declare it "
                f"in a policy module's CONSUMED_KNOBS, or justify the "
                f"asymmetry in tools/sacheck/config.py twin_renames"))
        # --- serve.py flag ---
        if name in cfg.flag_exempt:
            continue
        flag = cfg.flag_renames.get(name, "--" + name.replace("_", "-"))
        if flag not in flags:
            out.append(ctx.finding(
                NAME, cfg.sac_config_path, line, "missing-flag",
                f"serving knob SACConfig.{name} is not settable from "
                f"launch/serve.py (expected {flag}) — add the flag or "
                f"justify in flag_exempt"))

    # --- policy declarations must track the config (no rot) ---
    for name, (rel, line) in sorted(consumed.items()):
        if name not in sac_names:
            out.append(ctx.finding(
                NAME, rel, line, "stale-policy-knob",
                f"policy module declares CONSUMED_KNOBS entry {name!r} "
                f"but SACConfig has no such field — drop the entry or "
                f"restore the knob"))
        if name in cfg.twin_renames or name in cfg.twin_non_serving:
            out.append(Finding(
                NAME, cfg.sac_config_path, 1, "redundant-allowlist",
                f"SACConfig.{name} is consumed through the shared "
                f"policy package ({rel}) — its twin_renames/"
                f"twin_non_serving entry is redundant; drop it"))

    # --- stale allowlist entries (the allowlist must not rot) ---
    for table, code in ((cfg.twin_non_serving, "stale-allowlist"),
                        (cfg.twin_renames, "stale-allowlist"),
                        (cfg.flag_renames, "stale-allowlist"),
                        (cfg.flag_exempt, "stale-allowlist")):
        for name in table:
            if name not in sac_names:
                out.append(Finding(
                    NAME, cfg.sac_config_path, 1, code,
                    f"allowlist entry for SACConfig.{name} is stale — "
                    "the field no longer exists; drop the entry"))
    return out
