"""twin-coverage: every serving-relevant ``SACConfig`` knob must have a
``SimConfig`` twin with a MATCHING NAME and a ``launch/serve.py`` flag.

Why this invariant exists: the engine (real jitted decode) and the
simulator (analytic event loop) are deliberate twins — every PR's
acceptance rests on parity tests that run the same knob through both.
A knob that exists on one side only, or under a different name, silently
falls out of the parity harness: the next person sweeps
``replicate_horizon_steps`` on the engine and ``replicate_horizon`` on
the simulator and compares incomparable runs.  Exceptions are allowed
but must be *justified* in tools/sacheck/config.py (twin_renames /
twin_non_serving / flag_renames / flag_exempt) — and a justification
whose subject disappeared is itself reported (stale-allowlist), so the
allowlist cannot rot.
"""
from __future__ import annotations

import ast
from typing import List, Set

from tools.sacheck.core import (CheckContext, Finding, dataclass_fields)

NAME = "twin-coverage"


def _serve_flags(tree: ast.Module) -> Set[str]:
    flags: Set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            for arg in node.args:
                if (isinstance(arg, ast.Constant)
                        and isinstance(arg.value, str)
                        and arg.value.startswith("--")):
                    flags.add(arg.value)
    return flags


def run(ctx: CheckContext) -> List[Finding]:
    cfg = ctx.config
    out: List[Finding] = []

    sac_sf = ctx.file(cfg.sac_config_path)
    sim_sf = ctx.file(cfg.sim_config_path)
    serve_sf = ctx.file(cfg.serve_path)
    for path, sf in ((cfg.sac_config_path, sac_sf),
                     (cfg.sim_config_path, sim_sf),
                     (cfg.serve_path, serve_sf)):
        if sf is None or sf.tree is None:
            out.append(Finding(NAME, path, 1, "missing-file",
                               f"twin-coverage needs {path} but it is "
                               "absent or unparsable"))
    if any(sf is None or sf.tree is None
           for sf in (sac_sf, sim_sf, serve_sf)):
        return out

    sac_fields = dataclass_fields(sac_sf.tree, cfg.sac_config_class)
    sim_fields = {n for n, _ in dataclass_fields(sim_sf.tree,
                                                 cfg.sim_config_class)}
    flags = _serve_flags(serve_sf.tree)
    if not sac_fields:
        out.append(Finding(NAME, cfg.sac_config_path, 1, "missing-class",
                           f"class {cfg.sac_config_class} has no fields "
                           "(or was renamed away)"))
        return out

    sac_names = {n for n, _ in sac_fields}
    for name, line in sac_fields:
        if name in cfg.twin_non_serving:
            continue
        # --- SimConfig twin ---
        if name in cfg.twin_renames:
            twin, why = cfg.twin_renames[name]
            if twin is not None and twin not in sim_fields:
                out.append(ctx.finding(
                    NAME, cfg.sac_config_path, line, "stale-rename",
                    f"SACConfig.{name} is allowlisted as twinned to "
                    f"SimConfig.{twin}, but that field no longer exists "
                    f"(justification was: {why})"))
        elif name not in sim_fields:
            out.append(ctx.finding(
                NAME, cfg.sac_config_path, line, "missing-twin",
                f"serving knob SACConfig.{name} has no SimConfig field "
                f"of the same name — add the analytic twin, or justify "
                f"the asymmetry in tools/sacheck/config.py twin_renames"))
        # --- serve.py flag ---
        if name in cfg.flag_exempt:
            continue
        flag = cfg.flag_renames.get(name, "--" + name.replace("_", "-"))
        if flag not in flags:
            out.append(ctx.finding(
                NAME, cfg.sac_config_path, line, "missing-flag",
                f"serving knob SACConfig.{name} is not settable from "
                f"launch/serve.py (expected {flag}) — add the flag or "
                f"justify in flag_exempt"))

    # --- stale allowlist entries (the allowlist must not rot) ---
    for table, code in ((cfg.twin_non_serving, "stale-allowlist"),
                        (cfg.twin_renames, "stale-allowlist"),
                        (cfg.flag_renames, "stale-allowlist"),
                        (cfg.flag_exempt, "stale-allowlist")):
        for name in table:
            if name not in sac_names:
                out.append(Finding(
                    NAME, cfg.sac_config_path, 1, code,
                    f"allowlist entry for SACConfig.{name} is stale — "
                    "the field no longer exists; drop the entry"))
    return out
