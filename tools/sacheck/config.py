"""Repo-specific configuration of the sacheck passes.

Every allowlist entry carries a MANDATORY justification string — the
twin-coverage rule is "matching name or a justified allowlist entry",
and a reviewer should be able to audit each exception here without
digging through history.  An entry whose subject disappears from the
code is reported as stale by the pass that owns it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass
class SacheckConfig:
    # --- where the repo's twin declarations live --------------------------
    sac_config_path: str = "src/repro/configs/base.py"
    sac_config_class: str = "SACConfig"
    sim_config_path: str = "src/repro/serving/simulator.py"
    sim_config_class: str = "SimConfig"
    serve_path: str = "src/repro/launch/serve.py"
    # shared control-plane package (PR 10): a SACConfig knob declared in
    # a module-level CONSUMED_KNOBS tuple under this prefix is consumed
    # through ONE shared policy object by engine, simulator, and replay
    # alike, so twin-coverage drops the same-named-SimConfig-twin
    # requirement for it (the serve.py flag requirement stays)
    policy_package: str = "src/repro/serving/policy"

    # --- twin-coverage ----------------------------------------------------
    # SACConfig fields that are NOT serving knobs (model/kernel shape
    # parameters the analytic simulator has no use for).  field -> why.
    twin_non_serving: Dict[str, str] = dataclasses.field(default_factory=dict)
    # engine knob -> (SimConfig twin under a DIFFERENT name | None, why).
    # None means "no analytic twin exists, and that is deliberate".
    twin_renames: Dict[str, Tuple[Optional[str], str]] = \
        dataclasses.field(default_factory=dict)
    # engine knob -> serve.py flag spelled differently than
    # "--" + field.replace("_", "-").  field -> flag.
    flag_renames: Dict[str, str] = dataclasses.field(default_factory=dict)
    # engine knob -> why it has no serve.py flag at all.
    flag_exempt: Dict[str, str] = dataclasses.field(default_factory=dict)

    # --- accounting-boundary ---------------------------------------------
    # the ONE module allowed to mutate TrafficStats counters
    accounting_home: str = "src/repro/core/traffic.py"
    traffic_stats_class: str = "TrafficStats"
    # variable names treated as TrafficStats receivers when they are the
    # base of an attribute assignment (heuristic: the canonical accessor
    # is `<accountant>.stats.<counter>`)
    stats_receiver_names: Tuple[str, ...] = ("stats", "traffic_stats")

    # --- determinism ------------------------------------------------------
    # path prefixes whose set-iteration order feeds accounting/timing
    determinism_scopes: Tuple[str, ...] = ("src/repro/core/",
                                           "src/repro/serving/")

    # --- units ------------------------------------------------------------
    unit_suffixes: Tuple[str, ...] = ("_s", "_bytes", "_tokens", "_frac")


def repo_config() -> SacheckConfig:
    """The checked-in configuration for THIS repository."""
    cfg = SacheckConfig()
    cfg.twin_non_serving = {
        "enabled": "model-graph switch (dense vs DSA), not a serving knob",
        "topk": "attention-kernel shape; the sim reads it via ModelProfile",
        "d_idx": "lightning-indexer head dim — kernel shape only",
        "n_idx_heads": "lightning-indexer head count — kernel shape only",
        "pool_backend": "sim sweeps backends via BackendProfile instead",
        "interleave": "sim twin lives on BackendProfile.interleave",
        "overlap_fetch": "legacy pre-PR 2 knob superseded by overlap_frac",
        "kv_quant": "kernel-side pool quantization; no timing model yet "
                    "(ROADMAP compressed cold tier)",
    }
    cfg.twin_renames = {
        "device_buffer_size": (
            "device_buffer",
            "pre-PR 2 naming split; both sides are entries/layer/slot and "
            "every parity harness maps the pair explicitly (tests/parity.py)"),
        "layer_sizing": (
            "layer_buffer_sizes",
            "engine takes a sizing POLICY name, sim takes the realized "
            "per-layer sizes the policy produced"),
        "warmup_radix": (
            None,
            "radix-tail warm-up seeding is folded into the sim's single "
            "warmup_entries/warm_precision cold-start model"),
        "score_margin": (
            None,
            "score-threshold speculation shapes which entries are "
            "prefetched, not how many — invisible to the analytic width "
            "model (analytic_prefetch)"),
        "resize_epsilon": (
            None,
            "hysteresis only matters on NOISY measured miss rates; the "
            "analytic fixed point (analytic_resize) is noise-free"),
        "radix_headroom_frac": (
            None,
            "eviction headroom needs the real PoolAllocator; capacity "
            "effects deliberately stay with the engine (PR 5)"),
        # (disagg_prefill / prefill_lanes dropped in PR 10: both are now
        # consumed through serving/policy/prefill.py CONSUMED_KNOBS —
        # the shared PrefillSchedule supersedes the round1/
        # prefill_concurrency rename justifications)
    }
    cfg.flag_renames = {
        "device_buffer_size": "--device-buffer",
        "prefill_chunk_tokens": "--prefill-chunk",
        "disagg_prefill": "--disagg",
        "warmup_pressure_seed": "--warmup-pressure-seed",
        "slo_ttft_s": "--slo-ttft",
    }
    cfg.flag_exempt = {
        "enabled": "switched via --mode sac|dense",
        "pipeline_depth": "calibrated pipeline constant, not an operator "
                          "knob (PipelineModel depth)",
        "overlap_frac": "calibrated overlap constant measured from the "
                        "hardware, not an operator knob",
    }
    return cfg
