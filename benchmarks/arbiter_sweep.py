"""Fabric budget arbiter ablation: concurrency x arbitration on CXL.

Beyond-paper sweep (serving/arbiter.py): for each concurrent-request
count, the CXL backend runs the fetch pipeline (overlap + speculative
prefetch) with the cross-request budget arbiter off and on.  Reported
per cell: throughput, exposed fabric seconds, and the mean granted
speculative width — the point of arbitration is that as concurrency
grows and per-device links saturate, the arbiter trades useless tail
speculation for exposed-time headroom instead of letting every request
prefetch at full width.

Writes a ``BENCH_arbiter.json`` artifact (the `make bench-smoke` / CI
contract): one row per (concurrency, arbiter) cell.
"""
import argparse
import json

from benchmarks.common import PAPER_MODEL, run_cell

CONCURRENCIES = (16, 48, 96, 192)
CTX = 65536
WIDTH = 512
OVERLAP = 0.3     # tight hide window: the saturated regime arbitration
                  # exists for (at 0.85 the cut speculation was already
                  # hidden — only wasted bytes drop, not exposed time)


def run(csv=None, quick=False, out_json="BENCH_arbiter.json"):
    concs = CONCURRENCIES[:2] if quick else CONCURRENCIES
    n = 64 if quick else 384
    print("\n== Arbiter sweep: concurrency x budget arbitration (CXL) ==")
    rows = []
    for conc in concs:
        cells = {}
        for arb in (False, True):
            r = run_cell("cxl", ctx=CTX, n_requests=max(n, conc),
                         concurrency=conc, overlap_frac=OVERLAP,
                         prefetch_width=WIDTH, arbiter=arb,
                         min_prefetch_width=32)
            cells[arb] = r
            rows.append(dict(
                concurrency=conc, arbiter=arb,
                throughput_tok_s=r["throughput_tok_s"],
                exposed_fabric_s=r["exposed_fabric_s"],
                issued_fabric_s=r["issued_fabric_s"],
                hit_rate=r["sim_hit_rate"],
                prefetch_bytes=r["prefetch_bytes"],
                arbiter_width_mean=r.get("arbiter_width_mean")))
        off, on = cells[False], cells[True]
        gain = on["throughput_tok_s"] / off["throughput_tok_s"] - 1
        saved = off["exposed_fabric_s"] - on["exposed_fabric_s"]
        print(f"conc={conc:>4}  thr {off['throughput_tok_s']:.0f} -> "
              f"{on['throughput_tok_s']:.0f} ({gain*+100:+.1f}%)  "
              f"exposed {off['exposed_fabric_s']:.2f}s -> "
              f"{on['exposed_fabric_s']:.2f}s  "
              f"width {on['arbiter_width_mean']:.0f}/{WIDTH}")
        if csv is not None:
            csv.add(f"arbiter/conc{conc}", 0.0,
                    f"gain={gain*100:+.1f}% exposed_saved={saved:.2f}s")
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"model": PAPER_MODEL, "backend": "cxl",
                       "ctx": CTX, "prefetch_width": WIDTH,
                       "quick": quick, "rows": rows}, f, indent=2)
        print(f"wrote {out_json} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_arbiter.json")
    args = ap.parse_args()
    run(quick=args.quick, out_json=args.json)
