"""Regression gate over the fabric sweep artifact (PR 7).

Reads ``BENCH_fabric.json`` (written by benchmarks/fabric_sweep.py, the
last step of `make bench-smoke`) and fails — nonzero exit — when the
``tree_aware`` cell regresses out of its acceptance envelope at the
gated concurrencies.  The sweep puts 4 CXL devices behind 2 switch
trunks (``tree:4x2``) with two hot prefix groups; ``tree_blind`` runs
the same timing but a flat-accounting control plane (the pre-PR 7
baseline), ``tree_aware`` runs segment-aware placement pressure,
per-path arbiter budgets, replica-aware reads and warm-up seeding:

  - ``trunk_hotspot_aware`` > 1.05: the aware control plane stopped
    balancing the switch trunks (max/mean cumulative demand bytes over
    the trunk segments; 1.0 = balanced, 2.0 = one trunk carries
    everything — the blind cell's failure mode).
  - ``hotspot_win`` < 1.0: blind's trunk imbalance is no longer worse
    than aware's — the A/B contrast the subsystem exists to win
    collapsed (or the blind baseline accidentally became aware).
  - ``ttft_p99_ratio`` > 1.0: aware p99 TTFT no longer beats blind.
    The win comes from warm-up seeding splitting the hot groups across
    switches, so prefill pool-writes stop serializing on one trunk.
  - ``tbt_p99_ratio`` > 0.95: aware p99 TBT stopped clearly beating
    blind — replica-aware reads should split each hot prefix's decode
    fetches across its copies' trunks (observed ~0.77-0.83x).

Usage: ``python -m benchmarks.fabric_gate [--json BENCH_fabric.json]``
"""
import argparse
import json
import sys

GATED_CONCURRENCIES = (16, 32)
HOTSPOT_AWARE_MAX = 1.05
HOTSPOT_WIN_MIN = 1.0
TTFT_RATIO_MAX = 1.0
TBT_RATIO_MAX = 0.95


def check(doc: dict) -> list:
    """Return a list of failure strings (empty = gate passes)."""
    envelopes = {e["concurrency"]: e for e in doc.get("envelopes", [])}
    failures = []
    for conc in GATED_CONCURRENCIES:
        env = envelopes.get(conc)
        if env is None:
            failures.append(f"conc={conc}: no envelope row in artifact")
            continue
        hotspot = env.get("trunk_hotspot_aware", float("inf"))
        if hotspot > HOTSPOT_AWARE_MAX:
            failures.append(
                f"conc={conc}: trunk_hotspot_aware {hotspot:.3f} > "
                f"{HOTSPOT_AWARE_MAX} (aware trunks unbalanced)")
        win = env.get("hotspot_win", 0.0)
        if win < HOTSPOT_WIN_MIN:
            failures.append(
                f"conc={conc}: hotspot_win {win:.3f} < "
                f"{HOTSPOT_WIN_MIN} (blind no longer worse than aware)")
        ttft = env.get("ttft_p99_ratio", float("inf"))
        if ttft > TTFT_RATIO_MAX:
            failures.append(
                f"conc={conc}: ttft_p99_ratio {ttft:.3f} > "
                f"{TTFT_RATIO_MAX} (aware p99 TTFT stopped beating blind)")
        tbt = env.get("tbt_p99_ratio", float("inf"))
        if tbt > TBT_RATIO_MAX:
            failures.append(
                f"conc={conc}: tbt_p99_ratio {tbt:.3f} > "
                f"{TBT_RATIO_MAX} (replica-read TBT win lost)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_fabric.json")
    args = ap.parse_args(argv)
    try:
        with open(args.json) as f:
            doc = json.load(f)
    except OSError as e:
        print(f"fabric gate: cannot read {args.json}: {e}")
        return 2
    failures = check(doc)
    if failures:
        print("fabric gate: FAIL")
        for line in failures:
            print(f"  - {line}")
        return 1
    for e in doc.get("envelopes", []):
        if e["concurrency"] in GATED_CONCURRENCIES:
            print(f"fabric gate: conc={e['concurrency']} "
                  f"hotspot={e['trunk_hotspot_blind']:.3f}x->"
                  f"{e['trunk_hotspot_aware']:.3f}x "
                  f"ttft_p99={e['ttft_p99_ratio']:.3f}x "
                  f"tbt_p99={e['tbt_p99_ratio']:.3f}x  OK")
    print("fabric gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
