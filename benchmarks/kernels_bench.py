"""Kernel microbenches: jnp reference path wall-time on CPU (the pallas
kernels are TPU-target; interpret-mode timing is not meaningful, so we
time the ref path and report the kernels' derived VMEM working sets)."""
import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.kernels import ops


def run(csv=None, quick=False):
    print("\n== kernel microbenches (jnp ref path on CPU) ==")
    key = jax.random.PRNGKey(0)
    B, S, k = (4, 4096, 256) if quick else (8, 32768, 2048)
    d = 576
    kv = jax.random.normal(key, (B, S, d), jnp.bfloat16)
    idx = jax.random.randint(key, (B, k), 0, S)

    gather = jax.jit(lambda kv, idx: ops.batched_gather(kv, idx))
    us, _ = timed(lambda: jax.block_until_ready(gather(kv, idx)))
    if csv is not None:
        csv.add("kernels/gather_kv", us,
                f"B{B}xS{S}xk{k}; vmem_block={d*2}B/row")
    print(f"gather_kv        {us:10.1f} us   [B{B} S{S} k{k} d{d}]")

    ni, di = 8, 128
    q = jax.random.normal(key, (B, ni, di), jnp.bfloat16)
    w = jax.random.normal(key, (B, ni), jnp.bfloat16)
    keys = jax.random.normal(key, (B, S, di), jnp.bfloat16)
    idxer = jax.jit(lambda q, w, k_: ops.batched_indexer_scores(q, w, k_))
    us, _ = timed(lambda: jax.block_until_ready(idxer(q, w, keys)))
    if csv is not None:
        csv.add("kernels/indexer", us, f"B{B}xS{S}; block_s=512")
    print(f"indexer_scores   {us:10.1f} us   [B{B} S{S} di{di}]")

    H, dc, dr = 16, 512, 64
    q_lat = jax.random.normal(key, (B, H, dc), jnp.bfloat16)
    q_pe = jax.random.normal(key, (B, H, dr), jnp.bfloat16)
    entries = jax.random.normal(key, (B, k, dc + dr), jnp.bfloat16)
    valid = jnp.ones((B, k), bool)
    mla = jax.jit(lambda a, b_, c, v: ops.batched_sparse_mla(
        a, b_, c, v, dc=dc, scale=0.04))
    us, _ = timed(lambda: jax.block_until_ready(
        mla(q_lat, q_pe, entries, valid)))
    if csv is not None:
        csv.add("kernels/sparse_mla_attn", us, f"B{B}xk{k}; block_k=256")
    print(f"sparse_mla_attn  {us:10.1f} us   [B{B} k{k} dc{dc}]")

    pool = jnp.zeros((B, S, d), jnp.bfloat16)
    ent = jax.random.normal(key, (B, 64, d), jnp.bfloat16)
    sidx = jnp.tile(jnp.arange(64, dtype=jnp.int32)[None] * 3, (B, 1))
    scat = jax.jit(lambda p, e, i: ops.batched_scatter(p, e, i))
    us, _ = timed(lambda: jax.block_until_ready(scat(pool, ent, sidx)))
    if csv is not None:
        csv.add("kernels/scatter_kv", us, f"B{B}x64 rows")
    print(f"scatter_kv       {us:10.1f} us   [B{B} 64 rows]")


if __name__ == "__main__":
    run()
