"""Fig 9: Round-1 (cache populate) — prefill + pool write + cold decode.

Paper: CXL ~= RDMA ~= DRAM (prefill is compute-bound; both disaggregated
backends store KV comparably).
"""
from benchmarks.common import CTXS, run_cell


def run(csv=None, quick=False):
    ctxs = CTXS[:2] if quick else CTXS
    n = 64 if quick else 512
    print("\n== Fig 9: Round-1 cache populate (concurrency 8) ==")
    print(f"{'ctx':>6} {'cxl tok/s':>10} {'rdma tok/s':>11} {'dram tok/s':>11}"
          f" {'ttft_cxl_s':>11} {'ttft_rdma_s':>12}")
    for ctx in ctxs:
        out = {b: run_cell(b, ctx=ctx, concurrency=8, n_requests=n,
                           round1=True) for b in ("cxl", "rdma", "dram")}
        c, r, d = out["cxl"], out["rdma"], out["dram"]
        print(f"{ctx//1024:>5}K {c['throughput_tok_s']:>10.0f}"
              f" {r['throughput_tok_s']:>11.0f} {d['throughput_tok_s']:>11.0f}"
              f" {c['ttft_mean_s']:>11.2f} {r['ttft_mean_s']:>12.2f}")
        if csv is not None:
            csv.add(f"fig9/cxl/ctx{ctx//1024}k",
                    c["tbt_mean_s"] * 1e6,
                    f"thr={c['throughput_tok_s']:.0f}tok/s")
            csv.add(f"fig9/rdma/ctx{ctx//1024}k",
                    r["tbt_mean_s"] * 1e6,
                    f"thr={r['throughput_tok_s']:.0f}tok/s")
    print("paper: backends comparable in Round-1 (prefill compute-bound)")


if __name__ == "__main__":
    run()
