"""Placement-policy ablation: least_loaded vs pressure_aware on CXL.

Beyond-paper sweep (PR 4, core/placement.py): byte-balancing places a
long-context request as if it were proportionally heavy on the fabric,
but its per-step miss traffic grows only logarithmically with context —
so when a few mega-context requests share the pool with many short ones,
``least_loaded`` parks the short (demand-dense) requests together on one
link while the mega request's device idles.  ``pressure_aware`` reads
the live per-device demand seconds (the same ``TrafficStats`` signal the
budget arbiter consumes) and balances actual link pressure instead.

The trace is the regime where that matters: one mega-context request per
admission wave, a hot tier small enough that misses dominate the fabric,
and a tight hide window.  Reported per cell: throughput, exposed fabric
seconds, and mean TBT, placement-blind vs pressure-aware at equal hit
rate (placement never changes what is fetched, only from where).

Writes a ``BENCH_placement.json`` artifact (the `make bench-smoke` / CI
contract): one row per (concurrency, policy) cell.
"""
import argparse
import json

import numpy as np

from benchmarks.common import PAPER_MODEL, model_profile
from repro.serving.request import Request
from repro.serving.simulator import SimConfig, default_backends, simulate

CONCURRENCIES = (16, 32, 64)
BIG_CTX = 131072
SMALL_CTX = 16384
OUT_LEN = 256
BUFFER = 2048     # hot tier well under top-k coverage: misses dominate
OVERLAP = 0.3     # tight hide window (the saturated regime)


def skewed_trace(n: int, *, wave: int, seed: int = 1):
    """One mega-context request per ``wave`` admissions, the rest short:
    the byte-vs-pressure mismatch placement policies disagree on."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        big = (i % wave == 0)
        ctx = (BIG_CTX if big
               else int(SMALL_CTX * (1 + 0.2 * (rng.random() * 2 - 1))))
        reqs.append(Request(i, 0.0, ctx, OUT_LEN))
    return reqs


def run(csv=None, quick=False, out_json="BENCH_placement.json"):
    concs = CONCURRENCIES[:2] if quick else CONCURRENCIES
    model = model_profile()
    backend = default_backends()["cxl"]
    print("\n== Placement sweep: least_loaded vs pressure_aware (CXL) ==")
    rows = []
    for conc in concs:
        n = conc * (4 if quick else 6)
        reqs = skewed_trace(n, wave=conc)
        cells = {}
        for policy in ("least_loaded", "pressure_aware"):
            r = simulate(reqs, model, backend,
                         SimConfig(concurrency=conc, overlap_frac=OVERLAP,
                                   device_buffer=BUFFER,
                                   placement=policy))
            cells[policy] = r
            rows.append(dict(
                concurrency=conc, placement=policy,
                throughput_tok_s=r["throughput_tok_s"],
                exposed_fabric_s=r["exposed_fabric_s"],
                issued_fabric_s=r["issued_fabric_s"],
                tbt_mean_s=r["tbt_mean_s"],
                hit_rate=r["sim_hit_rate"]))
        ll, pa = cells["least_loaded"], cells["pressure_aware"]
        gain = pa["throughput_tok_s"] / ll["throughput_tok_s"] - 1
        saved = ll["exposed_fabric_s"] - pa["exposed_fabric_s"]
        print(f"conc={conc:>4}  thr {ll['throughput_tok_s']:.0f} -> "
              f"{pa['throughput_tok_s']:.0f} ({gain*+100:+.1f}%)  "
              f"exposed {ll['exposed_fabric_s']:.2f}s -> "
              f"{pa['exposed_fabric_s']:.2f}s")
        if csv is not None:
            csv.add(f"placement/conc{conc}", 0.0,
                    f"gain={gain*100:+.1f}% exposed_saved={saved:.2f}s")
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"model": PAPER_MODEL, "backend": "cxl",
                       "big_ctx": BIG_CTX, "small_ctx": SMALL_CTX,
                       "device_buffer": BUFFER, "quick": quick,
                       "rows": rows}, f, indent=2)
        print(f"wrote {out_json} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_placement.json")
    args = ap.parse_args()
    run(quick=args.quick, out_json=args.json)
