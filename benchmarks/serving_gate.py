"""Regression gate over the serving sweep artifact (PR 8).

Reads ``BENCH_serving.json`` (written by benchmarks/serving_sweep.py,
the last step of `make bench-smoke`) and fails — nonzero exit — when
continuous batching / disaggregated prefill regress out of their
acceptance envelope on the open-loop diurnal/burst trace:

  - ``ttft_honesty`` < 0: a cell's arrival-anchored p99 TTFT came out
    SMALLER than its dispatch-anchored p99 — impossible when admission
    is gated on ``arrival_s`` (queueing delay can only add latency), so
    a negative value means a request was dispatched before it arrived:
    the open-loop bug PR 8 fixed has come back.
  - ``chunked_gap_ratio`` > 0.5: chunked prefill stopped bounding the
    burst-induced decode stall — the p99 worst single token gap must
    stay well under the monolithic cell's whole-prompt stalls
    (observed ~0.15x at chunk=2048 on 16K effective prompts).
  - ``disagg_gap_ratio`` > 0.1: prefill/decode disaggregation stopped
    keeping prompts off the decode loop entirely (observed ~5e-4:
    decode's worst gap is just a decode step).
  - ``chunked_tbt_p99_ratio`` > 1.1: chunking made per-request mean
    TBT clearly WORSE than monolithic — the schedule should spread the
    same prefill compute, never add meaningfully to it.

Usage: ``python -m benchmarks.serving_gate [--json BENCH_serving.json]``
"""
import argparse
import json
import sys

TTFT_HONESTY_MIN = -1e-9
CHUNKED_GAP_MAX = 0.5
DISAGG_GAP_MAX = 0.1
CHUNKED_TBT_MAX = 1.1


def check(doc: dict) -> list:
    """Return a list of failure strings (empty = gate passes)."""
    envelopes = doc.get("envelopes", [])
    failures = []
    if not envelopes:
        return ["no envelope rows in artifact"]
    for env in envelopes:
        rate = env.get("rate", "?")
        honesty = env.get("ttft_honesty", -1.0)
        if honesty < TTFT_HONESTY_MIN:
            failures.append(
                f"rate={rate}: ttft_honesty {honesty:.4f}s < 0 "
                "(a request was dispatched before it arrived — the "
                "open-loop arrival bug is back)")
        gap = env.get("chunked_gap_ratio", float("inf"))
        if gap > CHUNKED_GAP_MAX:
            failures.append(
                f"rate={rate}: chunked_gap_ratio {gap:.3f} > "
                f"{CHUNKED_GAP_MAX} (chunked prefill stopped bounding "
                "the decode stall)")
        dgap = env.get("disagg_gap_ratio", float("inf"))
        if dgap > DISAGG_GAP_MAX:
            failures.append(
                f"rate={rate}: disagg_gap_ratio {dgap:.3f} > "
                f"{DISAGG_GAP_MAX} (disagg decode is stalling on "
                "prompts)")
        tbt = env.get("chunked_tbt_p99_ratio", float("inf"))
        if tbt > CHUNKED_TBT_MAX:
            failures.append(
                f"rate={rate}: chunked_tbt_p99_ratio {tbt:.3f} > "
                f"{CHUNKED_TBT_MAX} (chunking made mean TBT worse)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_serving.json")
    args = ap.parse_args(argv)
    try:
        with open(args.json) as f:
            doc = json.load(f)
    except OSError as e:
        print(f"serving gate: cannot read {args.json}: {e}")
        return 2
    failures = check(doc)
    if failures:
        print("serving gate: FAIL")
        for line in failures:
            print(f"  - {line}")
        return 1
    for e in doc.get("envelopes", []):
        print(f"serving gate: rate={e['rate']:g} "
              f"chunked_gap={e['chunked_gap_ratio']:.3f}x "
              f"disagg_gap={e['disagg_gap_ratio']:.4f}x "
              f"honesty={e['ttft_honesty']:+.4f}s  OK")
    print("serving gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
