"""Fabric topology sweep (PR 7): flat star vs 2-switch tree, segment-
blind vs segment-aware control plane, on the shared-prefix trace.

The question this sweep answers: when 4 pool devices sit behind 2
switches (``tree:4x2`` — each trunk is ONE device-link's worth of
upstream bandwidth, paper §A.2's PCIe-x8 uplink), does the PR 7
segment-aware control plane (bottleneck-segment placement pressure,
per-path arbiter budgets, replica-aware reads, warm-up pressure
seeding) actually relieve the trunk that the flat-accounting control
plane saturates?

The trace is the shared-prefix workload collapsed to TWO hot prefix
groups (``prefix_group %= 2`` — the acceptance regime: with flat
accounting, radix affinity parks both groups' owners on the lowest-
index devices, which sit behind the SAME switch).  Cells per
concurrency (all run the full PR 6 radix stack — replication, dedup,
radix admission — so the prefix-locality loop is live in every cell):

  - ``flat``       : ``flat:4`` — no shared segments; the reference for
    how much the tree timing itself costs.
  - ``tree_blind`` : ``tree:4x2`` with ``segment_aware=False`` — the
    A/B baseline.  Timing pays the shared trunks but the control plane
    still reads flat per-device endpoint demand, so radix affinity
    parks the hot prefix groups contiguously: both land behind ONE
    switch and its trunk serializes ~all fetch traffic.
  - ``tree_aware`` : ``tree:4x2`` with the PR 7 loop on
    (``segment_aware`` + ``replica_reads`` + ``warmup_pressure_seed``)
    — placement sees trunk pressure, grants budget per path, reads
    follow the least-pressured replica.

**The envelope metric.**  ``trunk_hotspot`` = max / mean of cumulative
demand bytes over the TRUNK segments (the bottleneck tier; leaf
segments are per-device and gated by benchmarks/locality_gate.py
already).  1.0 = both trunks carry equal traffic; 2.0 (the 2-trunk
worst case) = one trunk carries everything.  The gate
(benchmarks/fabric_gate.py) holds ``tree_aware`` to a hotspot AND a
p99-TTFT win over ``tree_blind``.

Writes ``BENCH_fabric.json`` (the `make bench-smoke` / CI artifact
contract): one row per (concurrency, cell) with p50/p99 TTFT/TBT and
the per-segment byte vectors, plus an ``envelopes`` section with the
acceptance ratios.
"""
import argparse
import dataclasses
import json

from benchmarks.common import PAPER_MODEL, model_profile
from repro.core.fabric import FabricTopology
from repro.serving.request import shared_prefix_trace
from repro.serving.simulator import SimConfig, default_backends, simulate

CONCURRENCIES = (16, 32, 64)
N_DEVICES = 4
TOPOLOGY = f"tree:{N_DEVICES}x2"
PREFIX = 32768
SUFFIX = 8192
OUT_LEN = 256
REUSE_P = 0.75
N_HOT = 2           # collapse the trace to two hot prefix groups
BUFFER = 2048
OVERLAP = 0.3
PREFETCH_W = 512

CELLS = ("flat", "tree_blind", "tree_aware")


def _sim_cfg(conc: int, cell: str) -> SimConfig:
    aware = cell == "tree_aware"
    return SimConfig(
        concurrency=conc, round1=True, overlap_frac=OVERLAP,
        device_buffer=BUFFER, prefetch_width=PREFETCH_W, arbiter=True,
        radix_affinity=True, replicate_prefixes=True, dedup_pages=True,
        radix_admission=True,
        topology=f"flat:{N_DEVICES}" if cell == "flat" else TOPOLOGY,
        segment_aware=aware or cell == "flat",
        replica_reads=aware, warmup_pressure_seed=aware)


def _trunk_hotspot(seg_bytes, topo: FabricTopology) -> float:
    """max/mean cumulative demand bytes over the non-leaf (trunk)
    segments — 1.0 is perfectly balanced, n_trunks is one trunk
    carrying everything.  Generalizes the locality sweep's per-device
    hotspot to the switch tier."""
    trunks = [seg_bytes[s] for s in range(topo.n_devices, topo.n_segments)]
    if not trunks or sum(trunks) <= 0:
        return 1.0
    return max(trunks) / (sum(trunks) / len(trunks))


def run(csv=None, quick=False, out_json="BENCH_fabric.json"):
    concs = CONCURRENCIES[:2] if quick else CONCURRENCIES
    model = model_profile()
    backend = dataclasses.replace(default_backends()["cxl"],
                                  n_pool_devices=N_DEVICES)
    topo = FabricTopology.from_spec(TOPOLOGY)
    print(f"\n== Fabric sweep: flat vs {TOPOLOGY} blind vs aware "
          f"(CXL x{N_DEVICES}, shared-prefix reuse_p={REUSE_P}) ==")
    rows, envelopes = [], []
    for conc in concs:
        n = conc * (3 if quick else 5)
        cells = {}
        for cell in CELLS:
            reqs = shared_prefix_trace(
                n, prefix_len=PREFIX, suffix_len=SUFFIX,
                output_len=OUT_LEN, reuse_p=REUSE_P, seed=1)
            for req in reqs:        # two hot groups (acceptance regime)
                req.prefix_group %= N_HOT
            r = simulate(reqs, model, backend, _sim_cfg(conc, cell))
            r["trunk_hotspot"] = (
                _trunk_hotspot(r["segment_demand_bytes"], topo)
                if cell != "flat" else 1.0)
            cells[cell] = r
            rows.append(dict(
                concurrency=conc, cell=cell,
                ttft_mean_s=r["ttft_mean_s"],
                ttft_p50_s=r["ttft_p50_s"],
                ttft_p99_s=r["ttft_p99_s"],
                tbt_mean_s=r["tbt_mean_s"],
                tbt_p50_s=r["tbt_p50_s"],
                tbt_p99_s=r["tbt_p99_s"],
                throughput_tok_s=r["throughput_tok_s"],
                exposed_fabric_s=r["exposed_fabric_s"],
                critical_demand_bytes=r["critical_demand_bytes"],
                spec_yielded_s=r["spec_yielded_s"],
                replica_redirects=r["replica_redirects"],
                trunk_hotspot=r["trunk_hotspot"],
                segment_demand_bytes=r["segment_demand_bytes"]))
        bl, aw = cells["tree_blind"], cells["tree_aware"]
        env = dict(
            concurrency=conc,
            trunk_hotspot_blind=bl["trunk_hotspot"],
            trunk_hotspot_aware=aw["trunk_hotspot"],
            hotspot_win=(bl["trunk_hotspot"]
                         / max(aw["trunk_hotspot"], 1e-9)),
            ttft_p99_ratio=(aw["ttft_p99_s"]
                            / max(bl["ttft_p99_s"], 1e-12)),
            tbt_p99_ratio=(aw["tbt_p99_s"]
                           / max(bl["tbt_p99_s"], 1e-12)),
            tree_tax_blind=(bl["tbt_mean_s"]
                            / max(cells["flat"]["tbt_mean_s"], 1e-12)),
        )
        envelopes.append(env)
        print(f"conc={conc:>4}  trunk hotspot "
              f"{env['trunk_hotspot_blind']:.2f}x -> "
              f"{env['trunk_hotspot_aware']:.2f}x  "
              f"p99 ttft {bl['ttft_p99_s']:.2f}s -> "
              f"{aw['ttft_p99_s']:.2f}s "
              f"({env['ttft_p99_ratio']:.2f}x)  "
              f"p99 tbt {bl['tbt_p99_s'] * 1e3:.1f}ms -> "
              f"{aw['tbt_p99_s'] * 1e3:.1f}ms  "
              f"redirects {aw['replica_redirects']:.0f}  "
              f"(blind -> aware)")
        if csv is not None:
            csv.add(f"fabric/conc{conc}", 0.0,
                    f"hotspot_win={env['hotspot_win']:.2f}x "
                    f"ttft_p99_ratio={env['ttft_p99_ratio']:.2f}x")
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"model": PAPER_MODEL, "backend": "cxl",
                       "topology": TOPOLOGY, "n_devices": N_DEVICES,
                       "prefix_len": PREFIX, "suffix_len": SUFFIX,
                       "reuse_p": REUSE_P, "device_buffer": BUFFER,
                       "quick": quick, "rows": rows,
                       "envelopes": envelopes}, f, indent=2)
        print(f"wrote {out_json} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_fabric.json")
    args = ap.parse_args()
    run(quick=args.quick, out_json=args.json)
