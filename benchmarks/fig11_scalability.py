"""Fig 11: decoding throughput vs concurrency (SAC vs RDMA).

Paper: up to 2.0x / 2.5x / 3.1x at 32K / 64K / 128K; RDMA plateaus on the
transmission bottleneck while SAC keeps scaling.
"""
from benchmarks.common import run_cell


def run(csv=None, quick=False):
    concs = (16, 64) if quick else (8, 16, 32, 64, 128)
    ctxs = (32768,) if quick else (32768, 65536, 131072)
    n = 64 if quick else 384
    print("\n== Fig 11: throughput scalability vs concurrency ==")
    for ctx in ctxs:
        best = 0.0
        line = [f"ctx={ctx//1024}K"]
        for conc in concs:
            c = run_cell("cxl", ctx=ctx, concurrency=conc, n_requests=n)
            r = run_cell("rdma", ctx=ctx, concurrency=conc, n_requests=n)
            ratio = c["throughput_tok_s"] / max(r["throughput_tok_s"], 1e-9)
            best = max(best, ratio)
            line.append(f"c{conc}: {c['throughput_tok_s']:.0f}/"
                        f"{r['throughput_tok_s']:.0f} (x{ratio:.2f})")
            if csv is not None:
                csv.add(f"fig11/ctx{ctx//1024}k/conc{conc}", 0.0,
                        f"cxl={c['throughput_tok_s']:.0f};"
                        f"rdma={r['throughput_tok_s']:.0f};x{ratio:.2f}")
        print("  ".join(line))
        print(f"  up to x{best:.2f} (paper: 2.0/2.5/3.1 at 32/64/128K)")


if __name__ == "__main__":
    run()
