"""Fig 12: SAC vs non-disaggregated baselines (local DRAM, GPU HBM only).

Paper: HBM wins at low concurrency but hits its capacity wall; SAC keeps
scaling (the case for a lower tier); SAC ~= DRAM throughout.

PR 8 makes these real three-backend runs: the SAC (cxl) cell runs the
DISAGGREGATED twin (``round1=True`` — separate prefill lanes write KV to
the pool over the fabric, decode adopts via handoff), while the dram/hbm
baseline cells run COLOCATED prefill (``colocated_prefill=True`` — the
prompt's prefill stalls the decode loop, the non-disaggregated serving
architecture the paper compares against).  The CSV metric is the cxl
throughput (tok/s) and the derived field carries the cxl/dram and
cxl/hbm throughput ratios, so ``benchmarks/run.py`` output feeds the
perf trajectory instead of the flat 0.0 rows the stub emitted.
"""
from benchmarks.common import run_cell


def run(csv=None, quick=False):
    concs = (16, 128) if quick else (8, 16, 32, 64, 128, 256)
    ctx = 131072
    n = 64 if quick else 256
    print("\n== Fig 12: non-disaggregated baselines (ctx 128K) ==")
    print(f"{'conc':>5} {'cxl':>7} {'dram':>7} {'hbm':>7}")
    for conc in concs:
        row = {"cxl": run_cell("cxl", ctx=ctx, concurrency=conc,
                               n_requests=n, round1=True)}
        for b in ("dram", "hbm"):
            # chunked colocated prefill: the strongest non-disaggregated
            # baseline (prompts splice in over bounded chunks instead of
            # stalling the batch on a whole 128K prefill)
            row[b] = run_cell(b, ctx=ctx, concurrency=conc, n_requests=n,
                              colocated_prefill=True,
                              prefill_chunk_tokens=2048)
        print(f"{conc:>5} {row['cxl']['throughput_tok_s']:>7.0f}"
              f" {row['dram']['throughput_tok_s']:>7.0f}"
              f" {row['hbm']['throughput_tok_s']:>7.0f}")
        if csv is not None:
            cxl = row["cxl"]["throughput_tok_s"]
            ratios = ";".join(
                f"cxl/{b}={cxl / max(row[b]['throughput_tok_s'], 1e-9):.3f}"
                for b in ("dram", "hbm"))
            csv.add(f"fig12/conc{conc}", cxl, ratios)
    print("paper: HBM plateaus at its KV capacity; SAC tracks DRAM")


if __name__ == "__main__":
    run()
