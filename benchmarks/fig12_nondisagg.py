"""Fig 12: SAC vs non-disaggregated baselines (local DRAM, GPU HBM only).

Paper: HBM wins at low concurrency but hits its capacity wall; SAC keeps
scaling (the case for a lower tier); SAC ~= DRAM throughout.
"""
from benchmarks.common import run_cell


def run(csv=None, quick=False):
    concs = (16, 128) if quick else (8, 16, 32, 64, 128, 256)
    ctx = 131072
    n = 64 if quick else 256
    print("\n== Fig 12: non-disaggregated baselines (ctx 128K) ==")
    print(f"{'conc':>5} {'cxl':>7} {'dram':>7} {'hbm':>7}")
    for conc in concs:
        row = {b: run_cell(b, ctx=ctx, concurrency=conc, n_requests=n)
               for b in ("cxl", "dram", "hbm")}
        print(f"{conc:>5} {row['cxl']['throughput_tok_s']:>7.0f}"
              f" {row['dram']['throughput_tok_s']:>7.0f}"
              f" {row['hbm']['throughput_tok_s']:>7.0f}")
        if csv is not None:
            csv.add(f"fig12/conc{conc}", 0.0,
                    ";".join(f"{b}={row[b]['throughput_tok_s']:.0f}"
                             for b in row))
    print("paper: HBM plateaus at its KV capacity; SAC tracks DRAM")


if __name__ == "__main__":
    run()
