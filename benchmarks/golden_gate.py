"""Golden-parity gate (PR 10): the bench-smoke sweeps must reproduce
their committed snapshots BIT-EXACTLY.

``make bench-smoke`` re-runs every quick sweep from scratch and writes
``BENCH_*.json`` at the repo root; this gate — the target's last step —
compares each artifact against its snapshot under ``benchmarks/golden/``
and fails (nonzero exit) on ANY differing leaf.  Every layer under test
is deterministic (virtual clocks, seeded traces, analytic models), so
equality here is exact — no tolerances: a control-plane refactor like
the PR 10 policy extraction may move code, never numbers, and a
one-ulp drift in a gate metric is a behavior change someone must own.

When a PR DELIBERATELY changes modeled behavior, regenerate the
snapshots and commit them with the change:

    make bench-smoke && cp BENCH_*.json benchmarks/golden/

Usage: ``python -m benchmarks.golden_gate [--golden-dir benchmarks/golden]``
"""
import argparse
import json
import math
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
GOLDEN_DIR = REPO / "benchmarks" / "golden"


def _leaves(node, prefix=""):
    """Flatten a JSON document into (path, value) pairs."""
    if isinstance(node, dict):
        for k in sorted(node):
            yield from _leaves(node[k], f"{prefix}/{k}")
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _leaves(v, f"{prefix}[{i}]")
    else:
        yield prefix, node


def _equal(a, b) -> bool:
    if isinstance(a, float) and isinstance(b, float):
        # bit-identity, except NaN compares equal to itself
        return a == b or (math.isnan(a) and math.isnan(b))
    return type(a) is type(b) and a == b


def diff(golden, fresh, limit: int = 5):
    """Leaf-level differences between two JSON documents (at most
    ``limit`` reported, plus a count of the remainder)."""
    g = dict(_leaves(golden))
    f = dict(_leaves(fresh))
    out = []
    for path in sorted(set(g) | set(f)):
        if path not in f:
            out.append(f"  {path}: missing from fresh run (was {g[path]!r})")
        elif path not in g:
            out.append(f"  {path}: new leaf {f[path]!r} not in golden")
        elif not _equal(g[path], f[path]):
            out.append(f"  {path}: golden {g[path]!r} != fresh {f[path]!r}")
    if len(out) > limit:
        out = out[:limit] + [f"  ... and {len(out) - limit} more"]
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--golden-dir", default=str(GOLDEN_DIR))
    ap.add_argument("--fresh-dir", default=str(REPO),
                    help="where the sweeps wrote BENCH_*.json")
    args = ap.parse_args(argv)
    golden_dir = pathlib.Path(args.golden_dir)
    fresh_dir = pathlib.Path(args.fresh_dir)

    goldens = sorted(golden_dir.glob("BENCH_*.json"))
    if not goldens:
        print(f"golden gate: no snapshots under {golden_dir} — run "
              "`make bench-smoke && cp BENCH_*.json benchmarks/golden/`")
        return 1
    failures = []
    for gpath in goldens:
        fpath = fresh_dir / gpath.name
        if not fpath.exists():
            failures.append(f"{gpath.name}: fresh artifact missing "
                            f"(sweep did not run?)")
            continue
        golden = json.loads(gpath.read_text())
        fresh = json.loads(fpath.read_text())
        lines = diff(golden, fresh)
        if lines:
            failures.append(f"{gpath.name}: {len(lines)} differing "
                            "leaves\n" + "\n".join(lines))
        else:
            print(f"golden gate: {gpath.name} bit-identical "
                  f"({sum(1 for _ in _leaves(golden))} leaves)  OK")
    if failures:
        print("golden gate: FAIL")
        for f in failures:
            print(f)
        print("(deliberate behavior change? regenerate: make bench-smoke"
              " && cp BENCH_*.json benchmarks/golden/)")
        return 1
    print("golden gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
