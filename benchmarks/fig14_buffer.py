"""Fig 14: HiSparse device_buffer_size ablation (4K vs 6K).

Paper: 6K beats 4K by +10.4% average (lower miss rate -> less fabric
traffic).
"""
import numpy as np

from benchmarks.common import CTXS, run_cell


def run(csv=None, quick=False):
    ctxs = CTXS[:2] if quick else CTXS
    n = 64 if quick else 384
    print("\n== Fig 14: device buffer size (4K vs 6K) ==")
    gains = []
    for ctx in ctxs:
        b6 = run_cell("cxl", ctx=ctx, n_requests=n, device_buffer=6144)
        b4 = run_cell("cxl", ctx=ctx, n_requests=n, device_buffer=4096)
        g = b6["throughput_tok_s"] / b4["throughput_tok_s"] - 1
        gains.append(g)
        print(f"ctx={ctx//1024:>3}K  6K={b6['throughput_tok_s']:.0f}"
              f"  4K={b4['throughput_tok_s']:.0f}  gain=+{g*100:.1f}%")
        if csv is not None:
            csv.add(f"fig14/ctx{ctx//1024}k", 0.0, f"gain=+{g*100:.1f}%")
    print(f"avg +{np.mean(gains)*100:.1f}% (paper +10.4%)")


if __name__ == "__main__":
    run()
