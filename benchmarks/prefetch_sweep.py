"""Fetch-pipeline ablation: speculative prefetch width x overlap on CXL.

Beyond-paper sweep (serving/prefetch.py): for each context length, the
CXL backend is run with the overlap queues on and a rising speculative
prefetch width.  Reported per cell: throughput, hot-tier hit rate, and
the issued vs exposed fabric split — the whole point of the pipeline is
that issued traffic grows (speculation is extra bytes) while *exposed*
step time shrinks.

Writes a ``BENCH_prefetch.json`` artifact (the `make bench-smoke` / CI
contract): one row per (ctx, width) cell plus the no-overlap baseline.
"""
import argparse
import json

import numpy as np

from benchmarks.common import CTXS, PAPER_MODEL, run_cell

WIDTHS = (0, 256, 512, 1024)


def run(csv=None, quick=False, out_json="BENCH_prefetch.json"):
    ctxs = CTXS[:2] if quick else CTXS
    n = 64 if quick else 384
    print("\n== Prefetch sweep: speculative width x overlap (CXL) ==")
    rows = []
    for ctx in ctxs:
        serial = run_cell("cxl", ctx=ctx, n_requests=n)   # seed semantics
        rows.append(dict(ctx=ctx, width=None, overlap=False,
                         throughput_tok_s=serial["throughput_tok_s"],
                         hit_rate=serial["sim_hit_rate"],
                         issued_fabric_s=serial["issued_fabric_s"],
                         exposed_fabric_s=serial["exposed_fabric_s"]))
        base_thr = serial["throughput_tok_s"]
        for w in WIDTHS:
            r = run_cell("cxl", ctx=ctx, n_requests=n,
                         overlap_frac=0.85, prefetch_width=w)
            gain = r["throughput_tok_s"] / base_thr - 1
            rows.append(dict(ctx=ctx, width=w, overlap=True,
                             throughput_tok_s=r["throughput_tok_s"],
                             hit_rate=r["sim_hit_rate"],
                             issued_fabric_s=r["issued_fabric_s"],
                             exposed_fabric_s=r["exposed_fabric_s"],
                             prefetch_bytes=r["prefetch_bytes"],
                             gain_vs_serial=gain))
            print(f"ctx={ctx//1024:>3}K w={w:>4}  "
                  f"thr={r['throughput_tok_s']:.0f} (+{gain*100:.1f}%)  "
                  f"hit={r['sim_hit_rate']:.4f}  "
                  f"exposed/issued="
                  f"{r['exposed_fabric_s']:.2f}/{r['issued_fabric_s']:.2f}s")
            if csv is not None:
                csv.add(f"prefetch/ctx{ctx//1024}k_w{w}", 0.0,
                        f"gain=+{gain*100:.1f}%")
    gains = [r["gain_vs_serial"] for r in rows
             if r.get("width") is not None]
    print(f"avg gain over serial CXL +{np.mean(gains)*100:.1f}%")
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"model": PAPER_MODEL, "backend": "cxl",
                       "quick": quick, "rows": rows}, f, indent=2)
        print(f"wrote {out_json} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_prefetch.json")
    args = ap.parse_args()
    run(quick=args.quick, out_json=args.json)
