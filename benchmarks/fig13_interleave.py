"""Fig 13: CXL device interleaving ablation.

Paper: interleaving across 2 devices beats 1 device by +9.2% avg,
peaking +14.2% at 128K.
"""
import numpy as np

from benchmarks.common import CTXS, run_cell


def run(csv=None, quick=False):
    ctxs = CTXS[:2] if quick else CTXS
    n = 64 if quick else 384
    print("\n== Fig 13: CXL device interleaving ==")
    gains = []
    for ctx in ctxs:
        two = run_cell("cxl", ctx=ctx, n_requests=n)
        one = run_cell("cxl", ctx=ctx, n_requests=n, n_pool_devices=1)
        g = two["throughput_tok_s"] / one["throughput_tok_s"] - 1
        gains.append(g)
        print(f"ctx={ctx//1024:>3}K  interleaved={two['throughput_tok_s']:.0f}"
              f"  single={one['throughput_tok_s']:.0f}  gain=+{g*100:.1f}%")
        if csv is not None:
            csv.add(f"fig13/ctx{ctx//1024}k", 0.0, f"gain=+{g*100:.1f}%")
    print(f"avg +{np.mean(gains)*100:.1f}% (paper +9.2%), "
          f"peak +{max(gains)*100:.1f}% (paper +14.2% @128K)")


if __name__ == "__main__":
    run()
