"""Open-loop serving sweep (PR 8): continuous batching + disaggregated
prefill under honest arrival-anchored SLO metrics.

The question this sweep answers: on an open-loop burst trace (the
``diurnal_trace`` workload generator — inhomogeneous Poisson arrivals,
burst clumps, heavy-tailed context lengths, multi-tenant prefix groups),
what do chunked prefill and prefill/decode disaggregation buy over the
monolithic colocated baseline, measured the honest way — TTFT anchored
on ``arrival_s`` (queueing delay included) and per-request TBT?

Cells per arrival rate (all on the cxl backend, same trace):

  - ``monolithic`` : ``colocated_prefill=True``, no chunking — every
    admitted prompt's full prefill stalls the decode loop (the
    pre-PR 8 serving architecture, now with arrival-gated admission).
  - ``chunked``    : ``colocated_prefill=True`` with
    ``prefill_chunk_tokens`` — prompts splice in over bounded chunks
    interleaved with decode steps, so a burst of long prompts costs
    each decode step one chunk, never a whole prefill.
  - ``disagg``     : ``round1=True`` — separate prefill lanes write KV
    to the pool over the fabric and decode adopts via handoff; decode
    never stalls on a prompt.

**Envelope metrics** (gated by benchmarks/serving_gate.py).  The
chunked-prefill win lives in ``tbt_max_p99_s`` — the p99 over each
request's WORST single inter-token gap: a monolithic prefill stalls
every decoding request for a whole prompt's compute (seconds), chunking
bounds that stall to one chunk.  Per-request mean TBT averages the
stall away, so it is reported but not the gated contrast.

  - ``chunked_gap_ratio``      = chunked / monolithic p99 worst token
    gap — chunking must bound the burst-induced decode stalls (< 1).
  - ``disagg_gap_ratio``       = disagg / monolithic p99 worst token
    gap — moving prefill off the decode loop cuts them hardest.
  - ``chunked_tbt_p99_ratio``  = chunked / monolithic p99 mean TBT
    (reported; secondary gate, weak contrast by construction).
  - ``ttft_honesty``           = arrival-anchored minus dispatch-
    anchored p99 TTFT, minimum over cells — the arrival-anchored
    number must never be smaller (queueing delay can only ADD
    latency); a negative value means a request was dispatched before
    it arrived (the open-loop bug PR 8 fixed).

Writes ``BENCH_serving.json``: one row per (rate, cell) with p50/p99
TTFT (both anchors) / TBT and SLO attainment, plus ``envelopes``.
"""
import argparse
import json

from benchmarks.common import PAPER_MODEL, model_profile
from repro.serving.request import diurnal_trace
from repro.serving.simulator import SimConfig, default_backends, simulate

# rates bracket the monolithic-colocated capacity (~1/prefill_s(16K)
# ≈ 0.5 req/s for the paper model): 0.25 = loaded but stable, 0.5 =
# at the knee, where burst clumps drive the p99 queueing tail
RATES = (0.25, 0.5)          # req/s (base; diurnal peak is 1.5x)
CONCURRENCY = 32
PREFIX = 8192
SUFFIX = 8192
OUT_LEN = 256
CHUNK = 2048
BURST_P = 0.08
BURST_SIZE = 8
CTX_TAIL_ALPHA = 2.5
N_TENANTS = 4
BUFFER = 2048
SLO_TTFT_S = 15.0
SLO_TBT_S = 0.200

CELLS = ("monolithic", "chunked", "disagg")


def _sim_cfg(cell: str) -> SimConfig:
    kw = dict(concurrency=CONCURRENCY, device_buffer=BUFFER,
              slo_ttft_s=SLO_TTFT_S, slo_tbt_s=SLO_TBT_S)
    if cell == "disagg":
        return SimConfig(round1=True, **kw)
    return SimConfig(colocated_prefill=True,
                     prefill_chunk_tokens=0 if cell == "monolithic"
                     else CHUNK, **kw)


def _trace(rate: float, n: int):
    return diurnal_trace(n, prefix_len=PREFIX, suffix_len=SUFFIX,
                         output_len=OUT_LEN, base_rate=rate, seed=2,
                         n_tenants=N_TENANTS, burst_p=BURST_P,
                         burst_size=BURST_SIZE,
                         ctx_tail_alpha=CTX_TAIL_ALPHA, max_ctx_mult=4.0)


def run(csv=None, quick=False, out_json="BENCH_serving.json"):
    rates = RATES[:1] if quick else RATES
    model = model_profile()
    backend = default_backends()["cxl"]
    print(f"\n== Serving sweep: open-loop diurnal/burst trace "
          f"(chunk={CHUNK}, burst_p={BURST_P}) ==")
    rows, envelopes = [], []
    for rate in rates:
        n = 96 if quick else 160
        cells = {}
        for cell in CELLS:
            r = simulate(_trace(rate, n), model, backend, _sim_cfg(cell))
            cells[cell] = r
            rows.append(dict(
                rate=rate, cell=cell, n_done=r["n_done"],
                throughput_tok_s=r["throughput_tok_s"],
                ttft_p50_s=r["ttft_p50_s"],
                ttft_p99_s=r["ttft_p99_s"],
                ttft_arrival_p50_s=r["ttft_arrival_p50_s"],
                ttft_arrival_p99_s=r["ttft_arrival_p99_s"],
                tbt_p50_s=r["tbt_p50_s"],
                tbt_p99_s=r["tbt_p99_s"],
                tbt_max_p50_s=r["tbt_max_p50_s"],
                tbt_max_p99_s=r["tbt_max_p99_s"],
                slo_ttft_attainment=r["slo_ttft_attainment"],
                slo_tbt_attainment=r["slo_tbt_attainment"]))
        mono, chk, dis = (cells[c] for c in CELLS)
        env = dict(
            rate=rate,
            chunked_gap_ratio=(chk["tbt_max_p99_s"]
                               / max(mono["tbt_max_p99_s"], 1e-12)),
            disagg_gap_ratio=(dis["tbt_max_p99_s"]
                              / max(mono["tbt_max_p99_s"], 1e-12)),
            chunked_tbt_p99_ratio=(chk["tbt_p99_s"]
                                   / max(mono["tbt_p99_s"], 1e-12)),
            disagg_tbt_p99_ratio=(dis["tbt_p99_s"]
                                  / max(mono["tbt_p99_s"], 1e-12)),
            ttft_honesty=min(
                c["ttft_arrival_p99_s"] - c["ttft_p99_s"]
                for c in cells.values()),
            disagg_ttft_p99_ratio=(
                dis["ttft_arrival_p99_s"]
                / max(mono["ttft_arrival_p99_s"], 1e-12)),
        )
        envelopes.append(env)
        print(f"rate={rate:>5.2f}  p99 worst-gap "
              f"{mono['tbt_max_p99_s']:.2f}s -> "
              f"{chk['tbt_max_p99_s']:.2f}s (chunked, "
              f"{env['chunked_gap_ratio']:.2f}x) -> "
              f"{dis['tbt_max_p99_s'] * 1e3:.0f}ms (disagg)  "
              f"p99 arrival-ttft {mono['ttft_arrival_p99_s']:.1f}s / "
              f"{chk['ttft_arrival_p99_s']:.1f}s / "
              f"{dis['ttft_arrival_p99_s']:.1f}s  "
              f"slo_tbt {mono['slo_tbt_attainment']:.2f} / "
              f"{chk['slo_tbt_attainment']:.2f} / "
              f"{dis['slo_tbt_attainment']:.2f}")
        if csv is not None:
            csv.add(f"serving/rate{rate:g}",
                    mono["tbt_max_p99_s"] * 1e6,
                    f"chunked_gap={env['chunked_gap_ratio']:.3f}x;"
                    f"disagg_gap={env['disagg_gap_ratio']:.3f}x")
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"model": PAPER_MODEL, "backend": "cxl",
                       "prefix_len": PREFIX, "suffix_len": SUFFIX,
                       "output_len": OUT_LEN, "chunk_tokens": CHUNK,
                       "burst_p": BURST_P, "burst_size": BURST_SIZE,
                       "slo_ttft_s": SLO_TTFT_S, "slo_tbt_s": SLO_TBT_S,
                       "concurrency": CONCURRENCY, "quick": quick,
                       "rows": rows, "envelopes": envelopes}, f,
                      indent=2)
        print(f"wrote {out_json} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_serving.json")
    args = ap.parse_args()
    run(quick=args.quick, out_json=args.json)
