"""Paper Appendix D reproductions.

D.1 statistical stability (CV of repeated runs), D.2 varying output
lengths (2K/4K/8K; the SAC advantage is largest for short outputs where
the RDMA "transmission tax" is least amortized), D.3 tail latency
(p99 vs mean under concurrency), D.4 request-level throughput.
"""
import numpy as np

from benchmarks.common import model_profile, run_cell
from repro.serving.request import sharegpt_trace
from repro.serving.simulator import SimConfig, default_backends, simulate


def run(csv=None, quick=False):
    n = 64 if quick else 256
    ctx = 65536

    # ---- D.1: coefficient of variation across seeds ----
    print("\n== D.1: stability (CV over 3 seeds) ==")
    model = model_profile()
    b = default_backends()["cxl"]
    thr = [simulate(sharegpt_trace(n, context_len=ctx, output_len=512,
                                   seed=s), model, b,
                    SimConfig(concurrency=64))["throughput_tok_s"]
           for s in (1, 2, 3)]
    cv = float(np.std(thr) / np.mean(thr) * 100)
    print(f"throughput CV = {cv:.2f}%  (paper: <2.1%)")
    if csv is not None:
        csv.add("appendixD/cv_throughput_pct", cv, "paper<2.1")

    # ---- D.2: output-length sweep ----
    print("\n== D.2: output lengths 1K/2K/4K (SAC vs RDMA gap shrinks) ==")
    gaps = []
    outs = (1024, 2048) if quick else (1024, 2048, 4096)
    for out_len in outs:
        c = run_cell("cxl", ctx=ctx, n_requests=n, output_len=out_len)
        r = run_cell("rdma", ctx=ctx, n_requests=n, output_len=out_len)
        g = c["throughput_tok_s"] / r["throughput_tok_s"]
        gaps.append(g)
        print(f"out={out_len:>5}: cxl {c['throughput_tok_s']:.0f} "
              f"rdma {r['throughput_tok_s']:.0f}  x{g:.2f}")
        if csv is not None:
            csv.add(f"appendixD/out{out_len}", 0.0, f"x{g:.2f}")
    assert gaps == sorted(gaps, reverse=True) or quick, \
        "gap should shrink as the transmission tax amortizes"
    print("paper: advantage largest at short outputs (transmission tax)")

    # ---- D.3: tail latency ----
    print("\n== D.3: tail latency (mean vs p99) ==")
    for name in ("cxl", "dram"):
        res = run_cell(name, ctx=ctx, n_requests=n)
        print(f"{name:>5}: tbt mean {res['tbt_mean_s']*1e3:.1f}ms "
              f"p99 {res['tbt_p99_s']*1e3:.1f}ms | "
              f"ttft mean {res['ttft_mean_s']:.2f}s "
              f"p99 {res['ttft_p99_s']:.2f}s")
        if csv is not None:
            csv.add(f"appendixD/{name}_tbt_p99", res["tbt_p99_s"] * 1e6,
                    f"mean={res['tbt_mean_s']*1e3:.1f}ms")

    # ---- D.4: request-level throughput ----
    print("\n== D.4: request throughput (req/s) ==")
    for name in ("cxl", "rdma", "dram"):
        res = run_cell(name, ctx=ctx, n_requests=n)
        print(f"{name:>5}: {res['throughput_req_s']:.3f} req/s")
        if csv is not None:
            csv.add(f"appendixD/{name}_req_s", 0.0,
                    f"{res['throughput_req_s']:.3f}")


if __name__ == "__main__":
    run()
