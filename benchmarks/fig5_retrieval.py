"""Fig 5: sparse KV retrieval latency across fabrics (calibrated models).

Paper: CXL within 1.04-1.64x of local DRAM; RDMA 4.0-19.7x, ms-level at
high entry counts.
"""
from repro.core.transfer import FABRICS, fig5_ratios

ENTRY_BYTES = 1152  # DeepSeek-V3.2 MLA entry (512+64 dims bf16)


def run(csv=None, quick=False):
    ns = (64, 256, 1024, 2048, 4096)
    print("\n== Fig 5: sparse retrieval latency (entry=1152B) ==")
    print(f"{'entries':>8} {'dram_us':>9} {'cxl_us':>9} {'rdma_us':>10} "
          f"{'cxl/dram':>9} {'rdma/dram':>10}")
    for n in ns:
        t = {f: FABRICS[f].sparse_fetch_time(n, ENTRY_BYTES) * 1e6
             for f in ("dram", "cxl", "rdma")}
        r = fig5_ratios(n, ENTRY_BYTES)
        print(f"{n:>8} {t['dram']:>9.1f} {t['cxl']:>9.1f} {t['rdma']:>10.1f}"
              f" {r['cxl']:>9.2f} {r['rdma']:>10.1f}")
        if csv is not None:
            csv.add(f"fig5/cxl/n{n}", t["cxl"],
                    f"ratio_vs_dram={r['cxl']:.2f}")
            csv.add(f"fig5/rdma/n{n}", t["rdma"],
                    f"ratio_vs_dram={r['rdma']:.1f}")
    print("paper bands: cxl 1.04-1.64x | rdma 4.0-19.7x (ms at high n)")


if __name__ == "__main__":
    run()
