"""Shared benchmark plumbing: trace construction, backend sweep, CSV rows."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.configs import get_config
from repro.serving.request import sharegpt_trace
from repro.serving.simulator import (SimConfig, default_backends,
                                     profile_from_config, simulate)

CTXS = (16384, 32768, 65536, 131072)
PAPER_MODEL = "deepseek-v32"


def model_profile(arch: str = PAPER_MODEL):
    return profile_from_config(get_config(arch))


def run_cell(backend_name: str, *, ctx: int, concurrency: int = 64,
             n_requests: int = 512, output_len: int = 1024,
             device_buffer: int = 6144, round1: bool = False,
             backends=None, arch: str = PAPER_MODEL, seed: int = 1,
             n_pool_devices: int = None, **sim_kw) -> Dict[str, float]:
    """``sim_kw`` passes through to SimConfig (e.g. the fetch-pipeline
    knobs ``prefetch_width`` / ``overlap_frac`` / ``pipeline_depth``)."""
    import dataclasses
    backends = backends or default_backends()
    b = backends[backend_name]
    if n_pool_devices is not None:
        b = dataclasses.replace(b, n_pool_devices=n_pool_devices,
                                interleave=n_pool_devices > 1)
    reqs = sharegpt_trace(n_requests, context_len=ctx,
                          output_len=output_len, seed=seed)
    return simulate(reqs, model_profile(arch), b,
                    SimConfig(concurrency=concurrency,
                              device_buffer=device_buffer, round1=round1,
                              **sim_kw))


class Csv:
    """Collect ``name,us_per_call,derived`` rows (the run.py contract)."""

    def __init__(self):
        self.rows: List[str] = []

    def add(self, name: str, us_per_call: float, derived: str = ""):
        self.rows.append(f"{name},{us_per_call:.3f},{derived}")

    def dump(self):
        print("name,us_per_call,derived")
        for r in self.rows:
            print(r)


def timed(fn, *args, repeat: int = 3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat * 1e6, out
