"""Prefix-locality ablation: pressure_aware vs radix_affinity vs
radix_replica on CXL.

Beyond-paper sweep (PR 5 radix affinity + PR 6 replication/dedup,
serving/radix.py + core/placement.py): on a shared-prefix workload
(system prompts, few-shot templates — requests reuse a cached prompt
prefix with probability ``REUSE_P``) the radix prefix cache only pays
off when placement puts a reusing request on a device that HOLDS its
cached prefix: reuse there skips the matched tokens' prefill recompute
and their pool write, while off-device the prefix would cross two
fabric links and is recomputed instead.

``pressure_aware`` balances link pressure but scatters prefix groups
across devices (every reuse is a coin flip); ``radix_affinity`` weighs
the locality benefit against the live pressure gap — and concentrates
hot prefixes on one link (the PR 5 exposed-fabric regression).
``radix_replica`` adds the PR 6 mechanisms: hot-prefix replication
(copy the prefix to the least-pressured device when corrected pressure
on the owning link covers the one-time copy cost), refcounted page
dedup (matched bytes are shared with the cache, not privately booked),
and radix-aware admission.  The acceptance claim: radix_replica keeps
the TTFT win (within 1.2x of radix_affinity) while the fabric hotspot
returns to <= 1.2x the pressure_aware envelope and pool bytes per
request drop.

**The envelope metric.**  The hotspot envelope is measured on
``critical_demand_bytes`` — the sum over decode steps of the MAX
per-SEGMENT fetch demand (PR 7 generalization, core/fabric.py; on
this sweep's flat-star default every device is its own segment, so
the value equals the old per-device max bit-for-bit), i.e. the
issued traffic serialized on each step's critical-path link.  Raw end-to-end exposed seconds are NOT
comparable across these cells: exposure accrues per step against a
hide window with a flat base-compute term, and the radix cells finish
prefill ~2-3x faster, so they run ~35% fewer (larger) decode steps —
each step pressure_aware additionally runs donates ~1.8 ms of free
hide window (measured by fitting exposed ~= A*imbalance + steps*D - E
across the three cells: D ~= -1.8 ms/step).  That volume effect is the
TTFT win itself, not the hotspot; total fetched bytes are identical
across all three policies.  ``critical_demand_bytes`` isolates exactly
the quantity replication flattens: pressure_aware's per-step balance
makes it the floor (ratio 1.0 by construction), PR 5 radix_affinity
concentrates hot prefixes to ~1.31x, replication returns it under
1.2x.  Raw exposed seconds are still reported per row for reference.

Writes a ``BENCH_locality.json`` artifact (the `make bench-smoke` / CI
contract, gated by benchmarks/locality_gate.py): one row per
(concurrency, policy) cell, p50/p99 latencies, pool bytes per request,
plus an ``envelopes`` section with the acceptance ratios.
"""
import argparse
import json

from benchmarks.common import PAPER_MODEL, model_profile
from repro.serving.request import shared_prefix_trace
from repro.serving.simulator import SimConfig, default_backends, simulate

CONCURRENCIES = (16, 32, 64)
PREFIX = 32768      # shared system-prompt / few-shot template tokens
SUFFIX = 8192       # private per-request tail
OUT_LEN = 256
REUSE_P = 0.75      # fraction of arrivals reusing a live prefix group
BUFFER = 2048
OVERLAP = 0.3

POLICIES = ("pressure_aware", "radix_affinity", "radix_replica")


def _sim_cfg(conc: int, policy: str) -> SimConfig:
    radix = policy != "pressure_aware"
    return SimConfig(concurrency=conc, round1=True, overlap_frac=OVERLAP,
                     device_buffer=BUFFER, radix_affinity=radix,
                     placement=None if radix else "pressure_aware",
                     replicate_prefixes=policy == "radix_replica",
                     dedup_pages=policy == "radix_replica",
                     radix_admission=policy == "radix_replica")


def run(csv=None, quick=False, out_json="BENCH_locality.json"):
    concs = CONCURRENCIES[:2] if quick else CONCURRENCIES
    model = model_profile()
    backend = default_backends()["cxl"]
    print("\n== Locality sweep: pressure_aware vs radix_affinity vs "
          f"radix_replica (CXL, shared-prefix reuse_p={REUSE_P}) ==")
    rows, envelopes = [], []
    for conc in concs:
        n = conc * (3 if quick else 5)
        cells = {}
        for policy in POLICIES:
            reqs = shared_prefix_trace(
                n, prefix_len=PREFIX, suffix_len=SUFFIX,
                output_len=OUT_LEN, reuse_p=REUSE_P, seed=1)
            r = simulate(reqs, model, backend, _sim_cfg(conc, policy))
            cells[policy] = r
            rows.append(dict(
                concurrency=conc, placement=policy,
                ttft_mean_s=r["ttft_mean_s"],
                ttft_p50_s=r["ttft_p50_s"],
                ttft_p99_s=r["ttft_p99_s"],
                tbt_mean_s=r["tbt_mean_s"],
                tbt_p50_s=r["tbt_p50_s"],
                tbt_p99_s=r["tbt_p99_s"],
                bytes_written=r["bytes_written"],
                critical_demand_bytes=r.get("critical_demand_bytes", 0.0),
                radix_hit_tokens=r["radix_hit_tokens"],
                replicated_bytes=r.get("replicated_bytes", 0.0),
                dedup_shared_bytes=r.get("dedup_shared_bytes", 0.0),
                pool_bytes_per_req=r.get("pool_bytes_per_req", 0.0),
                throughput_tok_s=r["throughput_tok_s"],
                exposed_fabric_s=r["exposed_fabric_s"],
                hit_rate=r["sim_hit_rate"]))
        pa = cells["pressure_aware"]
        ra = cells["radix_affinity"]
        rr = cells["radix_replica"]
        # the acceptance envelope (benchmarks/locality_gate.py contract):
        # critical-link demand vs the pressure_aware envelope (see the
        # module docstring for why raw exposed seconds are not the
        # metric), the TTFT win vs pressure_aware, replica TTFT vs the
        # affinity baseline, and the dedup pool-byte saving
        env = dict(
            concurrency=conc,
            hotspot_ratio_affinity=(ra["critical_demand_bytes"]
                                    / max(pa["critical_demand_bytes"],
                                          1e-9)),
            hotspot_ratio_replica=(rr["critical_demand_bytes"]
                                   / max(pa["critical_demand_bytes"],
                                         1e-9)),
            exposed_ratio_affinity=(ra["exposed_fabric_s"]
                                    / max(pa["exposed_fabric_s"], 1e-9)),
            exposed_ratio_replica=(rr["exposed_fabric_s"]
                                   / max(pa["exposed_fabric_s"], 1e-9)),
            ttft_win_affinity=(pa["ttft_mean_s"]
                               / max(ra["ttft_mean_s"], 1e-12)),
            ttft_win_replica=(pa["ttft_mean_s"]
                              / max(rr["ttft_mean_s"], 1e-12)),
            ttft_replica_vs_affinity=(rr["ttft_mean_s"]
                                      / max(ra["ttft_mean_s"], 1e-12)),
            pool_bytes_ratio=(rr["pool_bytes_per_req"]
                              / max(ra["pool_bytes_per_req"], 1e-9)),
        )
        envelopes.append(env)
        print(f"conc={conc:>4}  ttft {pa['ttft_mean_s']:.2f}s / "
              f"{ra['ttft_mean_s']:.2f}s / {rr['ttft_mean_s']:.2f}s  "
              f"hotspot {env['hotspot_ratio_affinity']:.2f}x -> "
              f"{env['hotspot_ratio_replica']:.2f}x  "
              f"exposed {pa['exposed_fabric_s']:.2f}s / "
              f"{ra['exposed_fabric_s']:.2f}s / "
              f"{rr['exposed_fabric_s']:.2f}s  "
              f"pool B/req {ra['pool_bytes_per_req']:.2e} -> "
              f"{rr['pool_bytes_per_req']:.2e}  "
              f"(pa / affinity / replica)")
        if csv is not None:
            csv.add(f"locality/conc{conc}", 0.0,
                    f"ttft_win={env['ttft_win_replica']:.2f}x "
                    f"hotspot_ratio={env['hotspot_ratio_replica']:.2f}x "
                    f"pool_ratio={env['pool_bytes_ratio']:.2f}x")
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"model": PAPER_MODEL, "backend": "cxl",
                       "prefix_len": PREFIX, "suffix_len": SUFFIX,
                       "reuse_p": REUSE_P, "device_buffer": BUFFER,
                       "quick": quick, "rows": rows,
                       "envelopes": envelopes}, f, indent=2)
        print(f"wrote {out_json} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_locality.json")
    args = ap.parse_args()
    run(quick=args.quick, out_json=args.json)
