"""Prefix-locality ablation: radix_affinity vs pressure_aware on CXL.

Beyond-paper sweep (PR 5, serving/radix.py + core/placement.py): on a
shared-prefix workload (system prompts, few-shot templates — requests
reuse a cached prompt prefix with probability ``REUSE_P``) the radix
prefix cache only pays off when placement puts a reusing request on the
device that HOLDS its cached prefix: reuse there skips the matched
tokens' prefill recompute and their pool write (a device-local copy),
while off-device the prefix would cross two fabric links and is
recomputed instead.

``pressure_aware`` balances link pressure but scatters prefix groups
across devices (every reuse is a coin flip); ``radix_affinity`` weighs
the locality benefit (saved prefill + write seconds) against the live
pressure gap, capacity always winning.  Reported per cell: TTFT, prefill
write bytes, reused prefix tokens, and hit rate — the acceptance claim
is lower write bytes and TTFT at no hit-rate loss.

Writes a ``BENCH_locality.json`` artifact (the `make bench-smoke` / CI
contract): one row per (concurrency, policy) cell.
"""
import argparse
import json

from benchmarks.common import PAPER_MODEL, model_profile
from repro.serving.request import shared_prefix_trace
from repro.serving.simulator import SimConfig, default_backends, simulate

CONCURRENCIES = (16, 32, 64)
PREFIX = 32768      # shared system-prompt / few-shot template tokens
SUFFIX = 8192       # private per-request tail
OUT_LEN = 256
REUSE_P = 0.75      # fraction of arrivals reusing a live prefix group
BUFFER = 2048
OVERLAP = 0.3


def run(csv=None, quick=False, out_json="BENCH_locality.json"):
    concs = CONCURRENCIES[:2] if quick else CONCURRENCIES
    model = model_profile()
    backend = default_backends()["cxl"]
    print("\n== Locality sweep: pressure_aware vs radix_affinity (CXL, "
          f"shared-prefix reuse_p={REUSE_P}) ==")
    rows = []
    for conc in concs:
        n = conc * (3 if quick else 5)
        cells = {}
        for policy in ("pressure_aware", "radix_affinity"):
            reqs = shared_prefix_trace(
                n, prefix_len=PREFIX, suffix_len=SUFFIX,
                output_len=OUT_LEN, reuse_p=REUSE_P, seed=1)
            radix = policy == "radix_affinity"
            r = simulate(reqs, model, backend,
                         SimConfig(concurrency=conc, round1=True,
                                   overlap_frac=OVERLAP,
                                   device_buffer=BUFFER,
                                   radix_affinity=radix,
                                   placement=None if radix
                                   else "pressure_aware"))
            cells[policy] = r
            rows.append(dict(
                concurrency=conc, placement=policy,
                ttft_mean_s=r["ttft_mean_s"],
                bytes_written=r["bytes_written"],
                radix_hit_tokens=r["radix_hit_tokens"],
                throughput_tok_s=r["throughput_tok_s"],
                exposed_fabric_s=r["exposed_fabric_s"],
                hit_rate=r["sim_hit_rate"]))
        pa, ra = cells["pressure_aware"], cells["radix_affinity"]
        wr_cut = 1 - ra["bytes_written"] / max(pa["bytes_written"], 1e-9)
        ttft_cut = 1 - ra["ttft_mean_s"] / max(pa["ttft_mean_s"], 1e-12)
        print(f"conc={conc:>4}  ttft {pa['ttft_mean_s']:.2f}s -> "
              f"{ra['ttft_mean_s']:.2f}s ({ttft_cut*100:+.1f}%)  "
              f"written {pa['bytes_written']:.2e} -> "
              f"{ra['bytes_written']:.2e} ({wr_cut*100:+.1f}%)  "
              f"reused {ra['radix_hit_tokens']:.0f} tok  "
              f"hit {pa['sim_hit_rate']:.3f}/{ra['sim_hit_rate']:.3f}")
        if csv is not None:
            csv.add(f"locality/conc{conc}", 0.0,
                    f"ttft_cut={ttft_cut*100:+.1f}% "
                    f"write_cut={wr_cut*100:+.1f}%")
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"model": PAPER_MODEL, "backend": "cxl",
                       "prefix_len": PREFIX, "suffix_len": SUFFIX,
                       "reuse_p": REUSE_P, "device_buffer": BUFFER,
                       "quick": quick, "rows": rows}, f, indent=2)
        print(f"wrote {out_json} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_locality.json")
    args = ap.parse_args()
    run(quick=args.quick, out_json=args.json)
