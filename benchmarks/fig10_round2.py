"""Fig 10: Round-2 (cache hit) — the paper's headline numbers.

Paper: SAC = 2.1x RDMA throughput, 9.7x lower TTFT, 1.8x lower TBT;
within 91% of the local-DRAM upper bound.
"""
import numpy as np

from benchmarks.common import CTXS, run_cell


def run(csv=None, quick=False):
    ctxs = CTXS[:2] if quick else CTXS
    n = 64 if quick else 512
    print("\n== Fig 10: Round-2 cache hit (concurrency 64) ==")
    print(f"{'ctx':>6} {'cxl':>6} {'rdma':>6} {'dram':>6} | "
          f"{'thr x':>6} {'ttft x':>7} {'tbt x':>6} {'cxl/dram':>9}")
    ratios = []
    for ctx in ctxs:
        out = {b: run_cell(b, ctx=ctx, concurrency=64, n_requests=n)
               for b in ("cxl", "rdma", "dram")}
        c, r, d = out["cxl"], out["rdma"], out["dram"]
        row = (c["throughput_tok_s"] / r["throughput_tok_s"],
               r["ttft_mean_s"] / c["ttft_mean_s"],
               r["tbt_mean_s"] / c["tbt_mean_s"],
               c["throughput_tok_s"] / d["throughput_tok_s"])
        ratios.append(row)
        print(f"{ctx//1024:>5}K {c['throughput_tok_s']:>6.0f}"
              f" {r['throughput_tok_s']:>6.0f} {d['throughput_tok_s']:>6.0f}"
              f" | {row[0]:>6.2f} {row[1]:>7.1f} {row[2]:>6.2f}"
              f" {row[3]:>9.2f}")
        if csv is not None:
            csv.add(f"fig10/cxl/ctx{ctx//1024}k", c["tbt_mean_s"] * 1e6,
                    f"thr={c['throughput_tok_s']:.0f};ttft={c['ttft_mean_s']:.2f}s")
            csv.add(f"fig10/rdma/ctx{ctx//1024}k", r["tbt_mean_s"] * 1e6,
                    f"thr={r['throughput_tok_s']:.0f};ttft={r['ttft_mean_s']:.2f}s")
    a = np.mean(ratios, axis=0)
    print(f"AVG: thr x{a[0]:.2f} (paper 2.1) | ttft x{a[1]:.1f} (paper 9.7)"
          f" | tbt x{a[2]:.2f} (paper 1.8) | cxl/dram {a[3]:.2f} (paper 0.91)")
    if csv is not None:
        csv.add("fig10/avg_throughput_ratio", 0.0,
                f"x{a[0]:.2f}_vs_paper_2.1")
        csv.add("fig10/avg_tbt_ratio", 0.0, f"x{a[2]:.2f}_vs_paper_1.8")
    return a


if __name__ == "__main__":
    run()
