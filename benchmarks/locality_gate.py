"""Regression gate over the locality sweep artifact (PR 6).

Reads ``BENCH_locality.json`` (written by benchmarks/locality_sweep.py,
the last step of `make bench-smoke`) and fails — nonzero exit — when the
radix_replica cell regresses out of its acceptance envelope at the
gated concurrencies:

  - ``hotspot_ratio_replica`` > 1.2: the fabric hotspot is back.  The
    metric is critical-link demand bytes — since PR 7 the sum over
    decode steps of the max per-SEGMENT fetch demand (core/fabric.py;
    on the sweep's flat-star default each device IS its own segment,
    so the number is bit-identical to the old per-device max) —
    relative to the pressure_aware envelope.  See the sweep's module
    docstring for why raw end-to-end exposed seconds are NOT
    comparable across cells (the radix cells run ~35% fewer, larger
    decode steps; each extra step donates flat base-compute hide
    window, a volume effect that is the TTFT win itself, not the
    hotspot).  Switch topologies get their own gate:
    benchmarks/fabric_gate.py.
  - ``ttft_win_replica`` < 2.0: the radix TTFT win over pressure_aware
    was lost.
  - ``ttft_replica_vs_affinity`` > 1.2: replication/dedup/admission
    overhead ate the PR 5 latency win.
  - ``pool_bytes_ratio`` >= 1.0: page dedup stopped saving pool bytes
    per request vs the affinity baseline.

Usage: ``python -m benchmarks.locality_gate [--json BENCH_locality.json]``
"""
import argparse
import json
import sys

GATED_CONCURRENCIES = (16, 32)
HOTSPOT_MAX = 1.2
TTFT_WIN_MIN = 2.0
TTFT_VS_AFFINITY_MAX = 1.2
POOL_RATIO_MAX = 1.0


def check(doc: dict) -> list:
    """Return a list of failure strings (empty = gate passes)."""
    envelopes = {e["concurrency"]: e for e in doc.get("envelopes", [])}
    failures = []
    for conc in GATED_CONCURRENCIES:
        env = envelopes.get(conc)
        if env is None:
            failures.append(f"conc={conc}: no envelope row in artifact")
            continue
        hotspot = env.get("hotspot_ratio_replica", float("inf"))
        if hotspot > HOTSPOT_MAX:
            failures.append(
                f"conc={conc}: hotspot_ratio_replica {hotspot:.3f} > "
                f"{HOTSPOT_MAX} (critical-link demand vs pressure_aware)")
        win = env.get("ttft_win_replica", 0.0)
        if win < TTFT_WIN_MIN:
            failures.append(
                f"conc={conc}: ttft_win_replica {win:.2f}x < "
                f"{TTFT_WIN_MIN}x (radix TTFT win lost)")
        vs_aff = env.get("ttft_replica_vs_affinity", float("inf"))
        if vs_aff > TTFT_VS_AFFINITY_MAX:
            failures.append(
                f"conc={conc}: ttft_replica_vs_affinity {vs_aff:.3f} > "
                f"{TTFT_VS_AFFINITY_MAX} (replication overhead)")
        pool = env.get("pool_bytes_ratio", float("inf"))
        if pool >= POOL_RATIO_MAX:
            failures.append(
                f"conc={conc}: pool_bytes_ratio {pool:.3f} >= "
                f"{POOL_RATIO_MAX} (dedup saves nothing)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_locality.json")
    args = ap.parse_args(argv)
    try:
        with open(args.json) as f:
            doc = json.load(f)
    except OSError as e:
        print(f"locality gate: cannot read {args.json}: {e}")
        return 2
    failures = check(doc)
    if failures:
        print("locality gate: FAIL")
        for line in failures:
            print(f"  - {line}")
        return 1
    envs = doc.get("envelopes", [])
    for e in envs:
        if e["concurrency"] in GATED_CONCURRENCIES:
            print(f"locality gate: conc={e['concurrency']} "
                  f"hotspot={e['hotspot_ratio_replica']:.3f}x "
                  f"ttft_win={e['ttft_win_replica']:.2f}x "
                  f"pool={e['pool_bytes_ratio']:.2f}x  OK")
    print("locality gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
