"""§Roofline: aggregate results/dryrun/*.json into the per-cell table.

    PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
                                                 [--mesh single] [--md]
"""
import argparse
import glob
import json
import os
from typing import Dict, List

HW = "TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI"


def load(dir_: str, mesh: str = "single", mode: str = None) -> List[Dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        r = json.load(open(p))
        if r.get("mesh") != mesh:
            continue
        if mode and r.get("mode") != mode:
            continue
        rows.append(r)
    return rows


def fmt_ms(s):
    return f"{s*1e3:.2f}" if s is not None else "-"


def table(rows: List[Dict], md: bool = False) -> str:
    hdr = ["arch", "shape", "mode", "compute_ms", "memory_ms",
           "collective_ms", "dominant", "useful", "peak_GB/dev"]
    lines = []
    sep = " | " if md else "  "
    if md:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append(sep.join(f"{h:>13}" for h in hdr))
    for r in rows:
        if r.get("status") == "skipped":
            vals = [r["arch"], r["shape"], "-", "-", "-", "-",
                    "SKIP", "-", "-"]
        else:
            peak = (r["mem_per_device"].get("temp_bytes") or 0) + \
                (r["mem_per_device"].get("argument_bytes") or 0)
            vals = [r["arch"], r["shape"], r["mode"],
                    fmt_ms(r["compute_s"]), fmt_ms(r["memory_s"]),
                    fmt_ms(r["collective_s"]), r["dominant"],
                    (f"{r['useful_flops_ratio']:.3f}"
                     if r.get("useful_flops_ratio") else "-"),
                    f"{peak/1e9:.2f}"]
        if md:
            lines.append("| " + " | ".join(str(v) for v in vals) + " |")
        else:
            lines.append(sep.join(f"{str(v):>13}" for v in vals))
    return "\n".join(lines)


def run(csv=None, quick=False, dir_="results/dryrun"):
    rows = load(dir_, "single")
    if not rows:
        print(f"\n== roofline: no dry-run results in {dir_} ==")
        return
    print(f"\n== §Roofline baseline table ({len(rows)} cells, single-pod, "
          f"{HW}) ==")
    print(table(rows))
    if csv is not None:
        for r in rows:
            if r.get("status") == "skipped":
                continue
            dom_s = {"compute": r["compute_s"], "memory": r["memory_s"],
                     "collective": r["collective_s"]}[r["dominant"]]
            csv.add(f"roofline/{r['arch']}/{r['shape']}", dom_s * 1e6,
                    f"dominant={r['dominant']};useful="
                    f"{r.get('useful_flops_ratio') and round(r['useful_flops_ratio'], 3)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--mode", default=None)
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir, args.mesh, args.mode)
    print(table(rows, md=args.md))


if __name__ == "__main__":
    main()
