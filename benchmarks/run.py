"""Benchmark harness: one module per paper table/figure + kernel
microbenches + the roofline aggregation.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig10,fig13]

Prints each figure's reproduction against the paper's numbers, then a
``name,us_per_call,derived`` CSV block.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced traces (CI-speed)")
    ap.add_argument("--only", default="",
                    help="comma list: fig5,fig9,fig10,fig11,fig12,fig13,"
                         "fig14,prefetch,kernels,roofline")
    args = ap.parse_args()

    from benchmarks import (appendix_d, fig5_retrieval, fig9_round1,
                            fig10_round2, fig11_scalability, fig12_nondisagg,
                            fig13_interleave, fig14_buffer, kernels_bench,
                            prefetch_sweep, roofline)
    from benchmarks.common import Csv

    mods = {
        "fig5": fig5_retrieval, "fig9": fig9_round1, "fig10": fig10_round2,
        "fig11": fig11_scalability, "fig12": fig12_nondisagg,
        "fig13": fig13_interleave, "fig14": fig14_buffer,
        "prefetch": prefetch_sweep,
        "appendixD": appendix_d,
        "kernels": kernels_bench, "roofline": roofline,
    }
    only = [s.strip() for s in args.only.split(",") if s.strip()]
    csv = Csv()
    t0 = time.time()
    for name, mod in mods.items():
        if only and name not in only:
            continue
        mod.run(csv=csv, quick=args.quick)
    print(f"\n[benchmarks] total {time.time()-t0:.0f}s\n")
    csv.dump()


if __name__ == "__main__":
    main()
