# SAC reproduction — developer entry points.
#
#   make test        tier-1 suite (the ROADMAP verify command)
#   make test-fast   substrate + engine-buffer slice (quick signal)
#   make bench-smoke reduced buffer + prefetch + arbiter + placement +
#                    locality + fabric + serving sweeps; writes
#                    BENCH_prefetch.json + BENCH_arbiter.json +
#                    BENCH_placement.json + BENCH_locality.json +
#                    BENCH_fabric.json + BENCH_serving.json (CI
#                    artifacts), then gates the locality envelope
#                    (benchmarks/locality_gate.py: hotspot <= 1.2x
#                    pressure_aware, TTFT win >= 2x, dedup pool saving),
#                    the fabric envelope (benchmarks/fabric_gate.py:
#                    aware trunks balanced, aware p99 TTFT/TBT beat the
#                    segment-blind baseline on tree:4x2), and the
#                    serving envelope (benchmarks/serving_gate.py:
#                    arrival-anchored TTFT honest, chunked prefill
#                    bounds the p99 worst token gap, disagg decode
#                    never stalls on prompts), then the golden-parity
#                    gate (benchmarks/golden_gate.py: every re-run
#                    BENCH_*.json bit-identical to its committed
#                    snapshot under benchmarks/golden/ — refactors
#                    move code, never numbers)
#   make lint        sacheck (5 repo-invariant AST passes: twin-coverage,
#                    units, accounting-boundary, jit-purity, determinism;
#                    writes sacheck_report.json, new findings fail) +
#                    ruff (generic hygiene; skipped with a notice if not
#                    installed — the container may not ship it)
#   make deps        install runtime + test dependencies

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-fast bench-smoke lint deps

test:
	python -m pytest -x -q

test-fast:
	python -m pytest -q tests/test_placement.py tests/test_engine_buffer.py \
	    tests/test_prefetch.py tests/test_core_system.py \
	    tests/test_simulator.py

bench-smoke:
	python -c "from benchmarks.fig14_buffer import run; run(quick=True)"
	python -m benchmarks.prefetch_sweep --quick
	python -m benchmarks.arbiter_sweep --quick
	python -m benchmarks.placement_sweep --quick
	python -m benchmarks.locality_sweep --quick
	python -m benchmarks.locality_gate
	python -m benchmarks.fabric_sweep --quick
	python -m benchmarks.fabric_gate
	python -m benchmarks.serving_sweep --quick
	python -m benchmarks.serving_gate
	python -m benchmarks.golden_gate

lint:
	python -m tools.sacheck --json sacheck_report.json
	@if command -v ruff >/dev/null 2>&1; then \
	    ruff check .; \
	else \
	    echo "lint: ruff not installed — skipping (make deps installs it)"; \
	fi

deps:
	pip install -r requirements.txt
